//! NVLink remote-access path: the *other* 64 GB TLB the paper points at.
//!
//! Paper §1.2: "Section 1.4.3 of the tuning guide remarks on a 64GB NVLink
//! TLB for incoming remote requests, and it seems that this is not the only
//! 64GB TLB on the chip."  This module models that documented TLB: requests
//! arriving from peer GPUs over NVLink are translated by a single
//! device-level TLB (not per-SM-group!), then served by the same HBM
//! channels.
//!
//! Consequences, verified by the tests:
//!
//! * remote random access collapses past 64 GB exactly like Fig 1 — but
//!   since the NVLink TLB is a *single* shared structure, there is no
//!   group-to-chunk trick on the receiver side alone;
//! * the fix must come from the *senders*: restrict each peer's requests to
//!   a distinct < 64 GB window and the single TLB's working set still
//!   exceeds reach — windowing does NOT help unless the total touched
//!   region shrinks.  This asymmetry vs the SM-side TLBs is exactly why the
//!   paper's SM-group discovery matters: only resources that exist *per
//!   group* can be dodged by placement.

use crate::config::MachineConfig;
use crate::sim::access::{Pattern, Stream};
use crate::sim::calendar::{CalendarQueue, Event};
use crate::sim::pages::{line_of, page_of, page_shift};
use crate::sim::queue::{ns_to_ps, svc_ps, Ps, SingleServer};
use crate::sim::tlb::SetAssocTlb;
use crate::sim::walker::WalkerPool;
use crate::sim::hbm::Hbm;

/// The two event cores `run_remote` can drive: the production
/// [`CalendarQueue`] and the seed-style binary heap kept as the pop-order
/// oracle (mirroring `Machine::run` vs `Machine::run_reference_heap`).
trait EventQueue {
    fn push_event(&mut self, ev: Event);
    fn pop_event(&mut self) -> Option<Event>;
}

impl EventQueue for CalendarQueue {
    fn push_event(&mut self, ev: Event) {
        self.push(ev);
    }

    fn pop_event(&mut self) -> Option<Event> {
        self.pop()
    }
}

impl EventQueue for std::collections::BinaryHeap<std::cmp::Reverse<Event>> {
    fn push_event(&mut self, ev: Event) {
        self.push(std::cmp::Reverse(ev));
    }

    fn pop_event(&mut self) -> Option<Event> {
        self.pop().map(|std::cmp::Reverse(ev)| ev)
    }
}

/// NVLink ingress configuration.
#[derive(Debug, Clone, PartialEq)]
pub struct NvlinkConfig {
    /// Aggregate ingress bandwidth, GB/s (A100: 12 links x 25 = 300 GB/s
    /// per direction).
    pub ingress_gbps: f64,
    /// Entries of the remote-request TLB (64 GB reach at 2 MiB pages).
    pub tlb_entries: usize,
    pub tlb_assoc: usize,
    /// Extra link latency for a remote request, ns.
    pub link_latency_ns: f64,
    /// Walkers serving remote-TLB misses.
    pub walkers: usize,
    /// Remote requests a peer keeps in flight (NVLink buffering is deep;
    /// ~2k in-flight lines are needed to cover the ~850 ns remote latency
    /// at 300 GB/s).
    pub outstanding_per_peer: usize,
}

impl NvlinkConfig {
    pub fn a100() -> Self {
        Self {
            ingress_gbps: 300.0,
            tlb_entries: 32768,
            tlb_assoc: 8,
            link_latency_ns: 500.0,
            walkers: 8,
            outstanding_per_peer: 512,
        }
    }

    pub fn reach_bytes(&self, page_bytes: u64) -> u64 {
        self.tlb_entries as u64 * page_bytes
    }
}

/// One remote peer's request stream.
#[derive(Debug, Clone)]
pub struct PeerSpec {
    pub pattern: Pattern,
}

/// Result of a remote-access measurement.
#[derive(Debug, Clone)]
pub struct RemoteMeasurement {
    pub gbps: f64,
    pub tlb_hit_rate: f64,
    pub avg_latency_ns: f64,
}

/// Simulate `peers` issuing random remote reads into this device's memory.
///
/// Event model mirrors [`crate::sim::engine`] but with the single
/// device-level ingress path: link -> NVLink TLB (-> walker on miss) ->
/// HBM channel.  Completion events are ordered by the same indexed
/// [`CalendarQueue`] the engine uses; pops are in exact tuple order, so
/// results are bit-identical to the heap-driven loop kept as
/// [`run_remote_reference_heap`] (the equivalence property test below
/// mirrors the engine's).
pub fn run_remote(
    cfg: &MachineConfig,
    nv: &NvlinkConfig,
    peers: &[PeerSpec],
    accesses_per_peer: u64,
    seed: u64,
) -> RemoteMeasurement {
    let queue = CalendarQueue::new(peers.len() * nv.outstanding_per_peer + 1);
    run_remote_on(cfg, nv, peers, accesses_per_peer, seed, queue)
}

/// The seed-style `BinaryHeap` event loop, kept as the pop-order oracle
/// for the calendar-queue port.  Not a production path.
#[doc(hidden)]
pub fn run_remote_reference_heap(
    cfg: &MachineConfig,
    nv: &NvlinkConfig,
    peers: &[PeerSpec],
    accesses_per_peer: u64,
    seed: u64,
) -> RemoteMeasurement {
    let queue: std::collections::BinaryHeap<std::cmp::Reverse<Event>> =
        std::collections::BinaryHeap::with_capacity(peers.len() * nv.outstanding_per_peer + 1);
    run_remote_on(cfg, nv, peers, accesses_per_peer, seed, queue)
}

fn run_remote_on<Q: EventQueue>(
    cfg: &MachineConfig,
    nv: &NvlinkConfig,
    peers: &[PeerSpec],
    accesses_per_peer: u64,
    seed: u64,
    mut queue: Q,
) -> RemoteMeasurement {
    assert!(!peers.is_empty());
    let shift = page_shift(cfg.tlb.page_bytes);
    let link_lat = ns_to_ps(nv.link_latency_ns);
    let txn = crate::config::LINE_BYTES;
    let mut link = SingleServer::new();
    let link_svc = svc_ps(txn, nv.ingress_gbps);
    let mut tlb = SetAssocTlb::new(nv.tlb_entries, nv.tlb_assoc);
    let mut walkers = WalkerPool::new(nv.walkers, ns_to_ps(cfg.tlb.walk_ns));
    let mut hbm = Hbm::new(&cfg.memory, txn);

    // Pre-warm to steady state (same rationale as the engine).
    let cap = nv.tlb_entries as u64;
    {
        let mut regions = std::collections::BTreeMap::new();
        for p in peers {
            let r = p.pattern.region();
            regions.insert((r.base, r.len), r.pages(cfg.tlb.page_bytes));
        }
        let total: u64 = regions.values().sum();
        for (&(base, _), &pages) in &regions {
            let first = base >> shift;
            let take = if total <= cap {
                pages
            } else {
                (cap * pages / total).max(1)
            };
            for k in 0..take {
                tlb.insert(first + (k * pages) / take);
            }
        }
        tlb.reset_stats();
    }

    struct Peer {
        stream: Stream,
        issued: u64,
        completed: u64,
        warmup: u64,
        counted: u64,
        latency_sum: Ps,
    }
    let warmup = accesses_per_peer / 4;
    let mut state: Vec<Peer> = peers
        .iter()
        .enumerate()
        .map(|(i, p)| Peer {
            stream: Stream::new(p.pattern.clone(), seed ^ ((i as u64) << 24)),
            issued: 0,
            completed: 0,
            warmup,
            counted: 0,
            latency_sum: 0,
        })
        .collect();

    let issue = |state: &mut Vec<Peer>,
                     link: &mut SingleServer,
                     tlb: &mut SetAssocTlb,
                     walkers: &mut WalkerPool,
                     hbm: &mut Hbm,
                     pid: u32,
                     t: Ps|
     -> (Ps, Ps) {
        let p = &mut state[pid as usize];
        p.issued += 1;
        let addr = p.stream.next_addr();
        let page = page_of(addr, shift);
        let line = line_of(addr);
        // Cross the link, then translate at the single ingress TLB.
        let arrived = link.serve(t, link_svc) + link_lat;
        let ready = if tlb.lookup(page) {
            arrived.max(walkers.pending_completion(page).unwrap_or(0))
        } else {
            let done = walkers.walk(arrived, page);
            tlb.insert(page);
            done
        };
        (hbm.access(ready, line), t)
    };

    for k in 0..(nv.outstanding_per_peer as u64).min(accesses_per_peer) {
        for pid in 0..state.len() as u32 {
            let (done, issued) = issue(
                &mut state,
                &mut link,
                &mut tlb,
                &mut walkers,
                &mut hbm,
                pid,
                k * 700,
            );
            queue.push_event((done, pid, issued));
        }
    }

    let mut meas_start = Ps::MAX;
    let mut meas_end: Ps = 0;
    let mut counted_bytes = 0u64;
    while let Some((t, pid, issued)) = queue.pop_event() {
        let p = &mut state[pid as usize];
        p.completed += 1;
        if p.completed > p.warmup {
            p.counted += 1;
            p.latency_sum += t - issued;
            counted_bytes += txn;
            meas_start = meas_start.min(issued);
            meas_end = meas_end.max(t);
        }
        if p.issued < accesses_per_peer {
            let (done, t_issue) = issue(
                &mut state,
                &mut link,
                &mut tlb,
                &mut walkers,
                &mut hbm,
                pid,
                t,
            );
            queue.push_event((done, pid, t_issue));
        }
    }

    let window_s = meas_end.saturating_sub(meas_start).max(1) as f64 * 1e-12;
    let counted: u64 = state.iter().map(|p| p.counted).sum();
    let latency: Ps = state.iter().map(|p| p.latency_sum).sum();
    RemoteMeasurement {
        gbps: counted_bytes as f64 / 1e9 / window_s,
        tlb_hit_rate: {
            let (h, m) = (tlb.hits(), tlb.misses());
            if h + m == 0 {
                1.0
            } else {
                h as f64 / (h + m) as f64
            }
        },
        avg_latency_ns: if counted > 0 {
            latency as f64 / 1000.0 / counted as f64
        } else {
            0.0
        },
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{MachineConfig, GIB};
    use crate::sim::MemRegion;

    fn peers(n: usize, region: MemRegion) -> Vec<PeerSpec> {
        (0..n)
            .map(|_| PeerSpec {
                pattern: Pattern::Uniform(region),
            })
            .collect()
    }

    fn run(region_gib: u64, n_peers: usize) -> RemoteMeasurement {
        let cfg = MachineConfig::a100_80gb();
        let nv = NvlinkConfig::a100();
        run_remote(
            &cfg,
            &nv,
            &peers(n_peers, MemRegion::new(0, region_gib * GIB)),
            20_000,
            3,
        )
    }

    #[test]
    fn remote_reach_is_64_gib() {
        let nv = NvlinkConfig::a100();
        assert_eq!(nv.reach_bytes(2 << 20), 64 * GIB);
    }

    #[test]
    fn resident_remote_access_is_link_bound() {
        let m = run(32, 4);
        assert!(m.tlb_hit_rate > 0.99);
        // 4 peers x 256 outstanding saturate the 300 GB/s ingress.
        assert!(m.gbps > 240.0 && m.gbps <= 305.0, "{:.1} GB/s", m.gbps);
    }

    #[test]
    fn remote_thrash_collapses_like_fig1() {
        let resident = run(32, 4);
        let thrash = run(80, 4);
        assert!(thrash.tlb_hit_rate < 0.9);
        assert!(
            thrash.gbps < resident.gbps / 3.0,
            "remote cliff missing: {:.1} vs {:.1}",
            thrash.gbps,
            resident.gbps
        );
    }

    #[test]
    fn sender_side_windowing_alone_does_not_help() {
        // Peers each restricted to a distinct 20 GiB window of an 80 GiB
        // region: the single ingress TLB still sees 80 GiB of pages, so the
        // collapse remains — the asymmetry vs the per-group SM TLBs that
        // makes the paper's group discovery necessary.
        let cfg = MachineConfig::a100_80gb();
        let nv = NvlinkConfig::a100();
        let windows: Vec<PeerSpec> = (0..4)
            .map(|i| PeerSpec {
                pattern: Pattern::Uniform(MemRegion::new(i * 20 * GIB, 20 * GIB)),
            })
            .collect();
        let windowed = run_remote(&cfg, &nv, &windows, 20_000, 5);
        let uniform = run(80, 4);
        assert!(
            windowed.gbps < uniform.gbps * 1.6,
            "windowing should not restore remote speed: {:.1} vs {:.1}",
            windowed.gbps,
            uniform.gbps
        );
        assert!(windowed.tlb_hit_rate < 0.9);
    }

    #[test]
    fn shrinking_total_footprint_does_help() {
        // The only remote fix: total touched region <= reach.
        let small = run(60, 4);
        let big = run(80, 4);
        assert!(small.gbps > big.gbps * 2.0, "{:.1} vs {:.1}", small.gbps, big.gbps);
    }

    #[test]
    fn deterministic() {
        let a = run(80, 2);
        let b = run(80, 2);
        assert_eq!(a.gbps, b.gbps);
    }

    fn assert_bit_identical(a: &RemoteMeasurement, b: &RemoteMeasurement, what: &str) {
        assert_eq!(a.gbps.to_bits(), b.gbps.to_bits(), "{what}: gbps");
        assert_eq!(
            a.tlb_hit_rate.to_bits(),
            b.tlb_hit_rate.to_bits(),
            "{what}: tlb_hit_rate"
        );
        assert_eq!(
            a.avg_latency_ns.to_bits(),
            b.avg_latency_ns.to_bits(),
            "{what}: avg_latency_ns"
        );
    }

    #[test]
    fn calendar_matches_heap_on_resident_and_thrash() {
        let cfg = MachineConfig::a100_80gb();
        let nv = NvlinkConfig::a100();
        for gib in [32u64, 80] {
            let ps = peers(4, MemRegion::new(0, gib * GIB));
            assert_bit_identical(
                &run_remote(&cfg, &nv, &ps, 15_000, 3),
                &run_remote_reference_heap(&cfg, &nv, &ps, 15_000, 3),
                &format!("{gib} GiB"),
            );
        }
    }

    #[test]
    fn property_calendar_remote_is_bit_identical_to_heap() {
        // Mirrors the engine's calendar-vs-heap property test: random peer
        // counts, region shapes (incl. past-reach thrash that drives the
        // walker backlog over the calendar's ring horizon), and seeds.
        let cfg = MachineConfig::a100_80gb();
        crate::util::prop::check("nvlink-calendar-vs-heap", 15, |g| {
            let nv = NvlinkConfig::a100();
            let n_peers = g.usize(1, 5);
            let specs: Vec<PeerSpec> = (0..n_peers)
                .map(|_| {
                    let base = g.u64(0, 40) * GIB;
                    let len = g.u64(1, 80 - base / GIB) * GIB;
                    PeerSpec {
                        pattern: Pattern::Uniform(MemRegion::new(base, len)),
                    }
                })
                .collect();
            let accesses = g.u64(1_000, 8_000);
            let seed = g.u64(0, u64::MAX - 1);
            assert_bit_identical(
                &run_remote(&cfg, &nv, &specs, accesses, seed),
                &run_remote_reference_heap(&cfg, &nv, &specs, accesses, seed),
                &format!("case seed {}", g.case_seed),
            );
        });
    }
}

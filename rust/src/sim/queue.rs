//! Queueing primitives for the discrete-event engine.
//!
//! Time is integer **picoseconds** (`Ps`): avoids float-ordering issues in
//! the event heap and is fine-grained enough that sub-ns service times
//! (a 128 B transaction on a 130 GB/s port is ~985 ps) stay exact.
//!
//! Servers are work-conserving FIFO: an arrival at time `t` begins service
//! at `max(t, earliest-free-time)`.  This "virtual clock" formulation needs
//! no explicit queue storage and is exact for FIFO disciplines as long as
//! arrivals are presented in nondecreasing time order — which the engine's
//! event loop guarantees.

/// Simulated time in picoseconds.
pub type Ps = u64;

/// Picoseconds per nanosecond.
pub const PS_PER_NS: f64 = 1000.0;

#[inline]
pub fn ns_to_ps(ns: f64) -> Ps {
    (ns * PS_PER_NS).round() as Ps
}

#[inline]
pub fn ps_to_ns(ps: Ps) -> f64 {
    ps as f64 / PS_PER_NS
}

/// Service time (ps) for moving `bytes` through `gbps` GB/s of bandwidth.
/// (1 GB/s == 1 byte/ns == 0.001 byte/ps.)
#[inline]
pub fn svc_ps(bytes: u64, gbps: f64) -> Ps {
    ((bytes as f64 / gbps) * PS_PER_NS).round() as Ps
}

/// Single-server FIFO queue with arbitrary per-arrival service times.
#[derive(Debug, Clone, Default)]
pub struct SingleServer {
    next_free: Ps,
    busy: Ps,
    served: u64,
}

impl SingleServer {
    pub fn new() -> Self {
        Self::default()
    }

    /// Admit an arrival at `t` needing `svc` of service; returns completion
    /// time.  Queueing delay is `completion - t - svc`.
    #[inline]
    pub fn serve(&mut self, t: Ps, svc: Ps) -> Ps {
        let start = self.next_free.max(t);
        self.next_free = start + svc;
        self.busy += svc;
        self.served += 1;
        self.next_free
    }

    /// Total busy time (for utilization accounting).
    pub fn busy_ps(&self) -> Ps {
        self.busy
    }

    pub fn served(&self) -> u64 {
        self.served
    }

    pub fn next_free(&self) -> Ps {
        self.next_free
    }
}

/// k-server FIFO queue (e.g. a pool of page walkers).
///
/// Keeps the k per-server free times in a small vec; an arrival grabs the
/// earliest-free server.  O(k) per arrival, k <= 16 in practice.
#[derive(Debug, Clone)]
pub struct MultiServer {
    free_at: Vec<Ps>,
    busy: Ps,
    served: u64,
}

impl MultiServer {
    pub fn new(k: usize) -> Self {
        assert!(k >= 1);
        Self {
            free_at: vec![0; k],
            busy: 0,
            served: 0,
        }
    }

    /// Admit an arrival at `t` needing `svc`; returns completion time.
    #[inline]
    pub fn serve(&mut self, t: Ps, svc: Ps) -> Ps {
        let mut idx = 0;
        let mut best = self.free_at[0];
        for (i, &f) in self.free_at.iter().enumerate().skip(1) {
            if f < best {
                best = f;
                idx = i;
            }
        }
        let start = best.max(t);
        self.free_at[idx] = start + svc;
        self.busy += svc;
        self.served += 1;
        self.free_at[idx]
    }

    pub fn servers(&self) -> usize {
        self.free_at.len()
    }

    pub fn busy_ps(&self) -> Ps {
        self.busy
    }

    pub fn served(&self) -> u64 {
        self.served
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn time_conversions() {
        assert_eq!(ns_to_ps(1.0), 1000);
        assert_eq!(ns_to_ps(0.5), 500);
        assert!((ps_to_ns(2500) - 2.5).abs() < 1e-12);
        // 128 B at 128 GB/s = 1 ns.
        assert_eq!(svc_ps(128, 128.0), 1000);
    }

    #[test]
    fn single_server_idle_then_backlogged() {
        let mut s = SingleServer::new();
        // Idle server: completion = arrival + svc.
        assert_eq!(s.serve(1000, 500), 1500);
        // Arrival during service: queues behind.
        assert_eq!(s.serve(1200, 500), 2000);
        // Arrival after idle gap: no queueing.
        assert_eq!(s.serve(5000, 100), 5100);
        assert_eq!(s.served(), 3);
        assert_eq!(s.busy_ps(), 1100);
    }

    #[test]
    fn single_server_throughput_caps_at_service_rate() {
        let mut s = SingleServer::new();
        // Offer 1000 arrivals all at t=0, svc 10 each: last completes at 10_000.
        let mut last = 0;
        for _ in 0..1000 {
            last = s.serve(0, 10);
        }
        assert_eq!(last, 10_000);
    }

    #[test]
    fn multi_server_parallelism() {
        let mut m = MultiServer::new(4);
        // 4 arrivals at t=0 run in parallel.
        for _ in 0..4 {
            assert_eq!(m.serve(0, 100), 100);
        }
        // 5th queues behind the earliest-free.
        assert_eq!(m.serve(0, 100), 200);
    }

    #[test]
    fn multi_server_rate_is_k_times_single() {
        let k = 8;
        let mut m = MultiServer::new(k);
        let mut last = 0;
        for _ in 0..800 {
            last = m.serve(0, 100);
        }
        // 800 jobs, 8 servers, svc 100 -> makespan 100*800/8 = 10_000.
        assert_eq!(last, 10_000);
    }

    #[test]
    fn multi_server_respects_arrival_time() {
        let mut m = MultiServer::new(2);
        m.serve(0, 1000);
        m.serve(0, 1000);
        // Arrives when both busy until 1000.
        assert_eq!(m.serve(500, 100), 1100);
        // Arrives after everything drained.
        assert_eq!(m.serve(5000, 100), 5100);
    }
}

//! The substrate the paper's experiments ran on: a discrete-event model of
//! the A100 memory hierarchy.
//!
//! The paper measured physical silicon; we have none, so this module *is*
//! the card (DESIGN.md §2).  The pieces:
//!
//! * [`topology`] — GPC / TPC / SM tree, yield harvesting, the half-GPC
//!   **resource groups** the paper discovers, and the card-specific smid
//!   enumeration the probe must see through.
//! * [`tlb`] — set-associative LRU TLBs; the per-group instance has the
//!   64 GB reach the paper is about.
//! * [`walker`] — per-group page-walker pools (the cliff floor).
//! * [`port`] / [`hbm`] — per-group memory ports, per-GPC hubs, and
//!   line-striped HBM channels.
//! * [`access`] — the benchmark's address streams.
//! * [`calendar`] — the indexed calendar queue ordering completion events
//!   (O(1) amortized; the heap it replaced survives as the test oracle).
//! * [`engine`] — the event loop tying it together; produces
//!   [`stats::Measurement`]s with throughput in the paper's GB/s units.
//! * [`analytic`] — closed-form queueing predictions cross-validating the
//!   DES (and vice versa).
//! * [`fault`] — deterministic fault injection (stalls, outages, flapping
//!   health) keyed on per-group job clocks, for the resilience layer's
//!   chaos tests.

pub mod access;
pub mod analytic;
pub mod calendar;
pub mod engine;
pub mod fault;
pub mod hbm;
pub mod nvlink;
pub mod pages;
pub mod port;
pub mod queue;
pub mod stats;
pub mod tlb;
pub mod topology;
pub mod walker;

pub use access::Pattern;
pub use engine::{Machine, MeasurementSpec, SmAssignment};
pub use fault::{FaultInjector, FaultPlan, JobFault, StallKind};
pub use pages::MemRegion;
pub use stats::{GroupStats, Measurement};
pub use topology::{GroupId, SmId, Topology};

#[cfg(test)]
mod tests {
    //! Calibration tests: the simulated machine must land in the regimes
    //! the paper reports (DESIGN.md §6).  These use the full A100 preset
    //! with reduced access counts — minutes of silicon become milliseconds.

    use super::*;
    use crate::config::{MachineConfig, GIB};

    fn machine() -> Machine {
        Machine::new(MachineConfig::a100_80gb()).unwrap()
    }

    fn run_uniform(m: &Machine, sms: &[SmId], region: MemRegion, per_sm: u64) -> Measurement {
        m.run(&MeasurementSpec::uniform_all(
            sms,
            Pattern::Uniform(region),
            per_sm,
            42,
        ))
    }

    #[test]
    fn solo_sm_is_latency_bound_around_15_gbps() {
        let m = machine();
        let meas = run_uniform(&m, &[0], MemRegion::new(0, 4 * GIB), 20_000);
        // 48 outstanding x 128 B / ~390 ns -> ~15.5 GB/s (paper Fig 4 shows
        // ~120 GB/s for 8 SMs = 15 per SM).
        assert!(
            meas.gbps > 12.0 && meas.gbps < 19.0,
            "solo SM {:.1} GB/s",
            meas.gbps
        );
    }

    #[test]
    fn full_device_resident_hits_hbm_ceiling() {
        let m = machine();
        let meas = run_uniform(&m, &m.topology().all_sms(), MemRegion::new(0, 32 * GIB), 4_000);
        // Effective random-access ceiling = 1935 * 0.68 ~ 1316 GB/s; the
        // paper's Fig 1 plateau sits at ~1200-1300.
        assert!(
            meas.gbps > 1150.0 && meas.gbps < 1330.0,
            "full device {:.1} GB/s",
            meas.gbps
        );
        assert!(meas.tlb_hit_rate > 0.95, "hit rate {}", meas.tlb_hit_rate);
    }

    #[test]
    fn full_device_thrash_collapses() {
        let m = machine();
        let meas = run_uniform(&m, &m.topology().all_sms(), MemRegion::whole(80 * GIB), 4_000);
        // Past reach: walker-limited.  Must be a big drop (paper: "drops
        // off precipitously").
        assert!(meas.gbps < 450.0, "thrash {:.1} GB/s", meas.gbps);
        assert!(meas.tlb_hit_rate < 0.9);
    }

    #[test]
    fn group_to_chunk_restores_full_speed_at_80gib() {
        // The paper's headline result (Fig 6): restrict each *group* to one
        // 40 GiB half and the full 80 GiB is random-accessible at full speed.
        let m = machine();
        let page = m.config().tlb.page_bytes;
        let halves = MemRegion::whole(80 * GIB).split(2, page);
        let assignments: Vec<SmAssignment> = m
            .topology()
            .all_sms()
            .iter()
            .map(|&smid| SmAssignment {
                smid,
                pattern: Pattern::Uniform(halves[m.topology().group_of(smid) % 2]),
            })
            .collect();
        let meas = m.run(&MeasurementSpec {
            assignments,
            accesses_per_sm: 4_000,
            warmup_fraction: 0.25,
            txn_bytes: 128,
            seed: 7,
        });
        assert!(
            meas.gbps > 1150.0,
            "group-to-chunk {:.1} GB/s should be at ceiling",
            meas.gbps
        );
    }

    #[test]
    fn sm_to_chunk_gives_no_benefit() {
        // Paper Fig 1: halving per-SM does NOT help, because each group's
        // TLB still sees both halves.
        let m = machine();
        let page = m.config().tlb.page_bytes;
        let halves = MemRegion::whole(80 * GIB).split(2, page);
        let mut rng = crate::util::rng::Rng::seed_from_u64(3);
        let assignments: Vec<SmAssignment> = m
            .topology()
            .all_sms()
            .iter()
            .map(|&smid| SmAssignment {
                smid,
                pattern: Pattern::Uniform(halves[rng.gen_index(2)]),
            })
            .collect();
        let meas = m.run(&MeasurementSpec {
            assignments,
            accesses_per_sm: 4_000,
            warmup_fraction: 0.25,
            txn_bytes: 128,
            seed: 8,
        });
        assert!(
            meas.gbps < 500.0,
            "sm-to-chunk {:.1} GB/s should still thrash",
            meas.gbps
        );
    }

    #[test]
    fn solo_group_throughput_scales_with_sm_count() {
        // Paper Fig 4: 8-SM groups ~120 GB/s, 6-SM groups ~90, ratio 8/6.
        let m = machine();
        let groups = m.topology().groups_by_size();
        let big = *groups.first().unwrap();
        let small = *groups.last().unwrap();
        assert_eq!(m.topology().sms_in_group(big).len(), 8);
        assert_eq!(m.topology().sms_in_group(small).len(), 6);
        let region = MemRegion::new(0, 40 * GIB);
        let mb = run_uniform(&m, &m.topology().sms_in_group(big), region, 10_000);
        let ms = run_uniform(&m, &m.topology().sms_in_group(small), region, 10_000);
        let ratio = mb.gbps / ms.gbps;
        assert!(
            (ratio - 8.0 / 6.0).abs() < 0.15,
            "ratio {ratio:.3} (big {:.1}, small {:.1})",
            mb.gbps,
            ms.gbps
        );
        assert!(mb.gbps > 100.0 && mb.gbps < 140.0, "big {:.1}", mb.gbps);
    }

    #[test]
    fn two_groups_disjoint_regions_double_throughput() {
        // Paper Fig 5: pairs of groups in disjoint 40 GB regions achieve
        // ~2x a single group => no shared TLB between groups.
        let m = machine();
        let groups = m.topology().groups_by_size();
        let (g1, g2) = (groups[0], groups[1]);
        let r1 = MemRegion::new(0, 40 * GIB);
        let r2 = MemRegion::new(40 * GIB, 40 * GIB);
        let solo = run_uniform(&m, &m.topology().sms_in_group(g1), r1, 10_000);
        let mut assignments: Vec<SmAssignment> = Vec::new();
        for &smid in &m.topology().sms_in_group(g1) {
            assignments.push(SmAssignment {
                smid,
                pattern: Pattern::Uniform(r1),
            });
        }
        for &smid in &m.topology().sms_in_group(g2) {
            assignments.push(SmAssignment {
                smid,
                pattern: Pattern::Uniform(r2),
            });
        }
        let pair = m.run(&MeasurementSpec {
            assignments,
            accesses_per_sm: 10_000,
            warmup_fraction: 0.25,
            txn_bytes: 128,
            seed: 5,
        });
        let ratio = pair.gbps / solo.gbps;
        assert!(
            (ratio - 2.0).abs() < 0.2,
            "pair/solo = {ratio:.3} ({:.1}/{:.1})",
            pair.gbps,
            solo.gbps
        );
    }

    #[test]
    fn same_group_pair_halves_thrash_throughput() {
        // The probe signal (Fig 2): in thrash mode, two SMs sharing a group
        // share walkers -> ~half the throughput of two SMs in different
        // groups.
        let m = machine();
        let topo = m.topology();
        let g0 = topo.sms_in_group(0);
        let other_group = topo.group_of(
            (0..topo.sm_count())
                .find(|&s| topo.group_of(s) != 0)
                .unwrap(),
        );
        let g1 = topo.sms_in_group(other_group);
        let whole = MemRegion::whole(80 * GIB);
        let same = run_uniform(&m, &[g0[0], g0[1]], whole, 10_000);
        let diff = run_uniform(&m, &[g0[0], g1[0]], whole, 10_000);
        // A same-group pair shares one walker pool (saturating it) while a
        // cross-group pair gets two; the contrast is < 2x because a lone SM
        // already queues ~30 of its 48 warps on walks (latency-limited just
        // below walker saturation), but it stays clearly bimodal — which is
        // all the Fig-2/3 clustering needs.
        let ratio = diff.gbps / same.gbps;
        assert!(
            ratio > 1.25 && ratio < 2.4,
            "diff/same = {ratio:.3} ({:.2}/{:.2})",
            diff.gbps,
            same.gbps
        );
    }

    #[test]
    fn measurement_is_deterministic() {
        let m = machine();
        let a = run_uniform(&m, &[0, 5, 9], MemRegion::new(0, GIB), 5_000);
        let b = run_uniform(&m, &[0, 5, 9], MemRegion::new(0, GIB), 5_000);
        assert_eq!(a.gbps, b.gbps);
        assert_eq!(a.counted_accesses, b.counted_accesses);
    }

    #[test]
    fn sequential_beats_random_on_utlb() {
        let m = machine();
        let seq = m.run(&MeasurementSpec::uniform_all(
            &[0],
            Pattern::Sequential(MemRegion::new(0, GIB)),
            20_000,
            1,
        ));
        let rnd = run_uniform(&m, &[0], MemRegion::new(0, GIB), 20_000);
        assert!(seq.utlb_hit_rate > 0.99, "seq uTLB {}", seq.utlb_hit_rate);
        assert!(rnd.utlb_hit_rate < 0.2, "rnd uTLB {}", rnd.utlb_hit_rate);
        assert!(seq.avg_latency_ns < rnd.avg_latency_ns);
    }

    #[test]
    fn larger_transactions_raise_throughput() {
        // Paper §2.1 aside: 32x64-bit ~1400, 32x128-bit ~1600 GB/s.
        let m = machine();
        let sms = m.topology().all_sms();
        let mk = |txn: u64| {
            m.run(&MeasurementSpec {
                assignments: sms
                    .iter()
                    .map(|&smid| SmAssignment {
                        smid,
                        pattern: Pattern::Uniform(MemRegion::new(0, 32 * GIB)),
                    })
                    .collect(),
                accesses_per_sm: 4_000,
                warmup_fraction: 0.25,
                txn_bytes: txn,
                seed: 2,
            })
        };
        let t128 = mk(128).gbps;
        let t256 = mk(256).gbps;
        let t512 = mk(512).gbps;
        assert!(t256 > t128 * 1.02, "256B {t256:.0} vs 128B {t128:.0}");
        assert!(t512 > t256 * 1.05, "512B {t512:.0} vs 256B {t256:.0}");
        assert!(t256 > 1300.0 && t256 < 1500.0, "256B {t256:.0}");
        assert!(t512 > 1500.0 && t512 < 1700.0, "512B {t512:.0}");
    }
}

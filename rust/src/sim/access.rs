//! Access-stream generators: what addresses each SM's warps read.
//!
//! The paper's benchmark: "every warp reads random coalesced arrays of 32
//! 32-bit words" — i.e. a stream of uniformly random line addresses inside
//! some region.  Variants restrict the region per SM (the paper's
//! "SM-to-chunk"), per group ("group-to-chunk", the contribution), or use
//! non-uniform distributions for the workload studies.

use crate::config::LINE_BYTES;
use crate::util::rng::Rng;
use crate::sim::pages::MemRegion;

/// Address-stream shape for one SM.
#[derive(Debug, Clone, PartialEq)]
pub enum Pattern {
    /// Uniformly random lines in the region (the paper's benchmark).
    Uniform(MemRegion),
    /// Sequential line sweep from the region base (wraps).
    Sequential(MemRegion),
    /// Strided lines: `base + (k * stride_lines * LINE) % len` (wraps).
    Strided { region: MemRegion, stride_lines: u64 },
    /// Zipf-distributed lines (hot-spot workloads), s = `theta`.
    Zipf { region: MemRegion, theta: f64 },
}

impl Pattern {
    pub fn region(&self) -> &MemRegion {
        match self {
            Pattern::Uniform(r) | Pattern::Sequential(r) => r,
            Pattern::Strided { region, .. } | Pattern::Zipf { region, .. } => region,
        }
    }
}

/// Per-SM address generator (deterministic for a given seed).
#[derive(Debug, Clone)]
pub struct Stream {
    pattern: Pattern,
    rng: Rng,
    counter: u64,
    /// Zipf sampling state (rejection-inversion constants).
    zipf: Option<ZipfState>,
}

impl Stream {
    pub fn new(pattern: Pattern, seed: u64) -> Self {
        assert!(
            pattern.region().lines() > 0,
            "region must hold at least one line"
        );
        let zipf = match &pattern {
            Pattern::Zipf { region, theta } => Some(ZipfState::new(region.lines(), *theta)),
            _ => None,
        };
        Self {
            pattern,
            rng: Rng::seed_from_u64(seed),
            counter: 0,
            zipf,
        }
    }

    /// Next line-aligned byte address.
    #[inline]
    pub fn next_addr(&mut self) -> u64 {
        match &self.pattern {
            Pattern::Uniform(r) => {
                let line = self.rng.gen_range(r.lines());
                r.base + line * LINE_BYTES
            }
            Pattern::Sequential(r) => {
                let line = self.counter % r.lines();
                self.counter += 1;
                r.base + line * LINE_BYTES
            }
            Pattern::Strided {
                region,
                stride_lines,
            } => {
                let line = (self.counter * stride_lines) % region.lines();
                self.counter += 1;
                region.base + line * LINE_BYTES
            }
            Pattern::Zipf { region, .. } => {
                let z = self.zipf.as_mut().unwrap();
                let rank = z.sample(&mut self.rng);
                // Scatter ranks over the region so hot lines are not all in
                // the first pages (rank r -> line via multiplicative hash).
                let line = rank.wrapping_mul(0x9E37_79B9_7F4A_7C15) % region.lines();
                region.base + line * LINE_BYTES
            }
        }
    }

    pub fn pattern(&self) -> &Pattern {
        &self.pattern
    }
}

/// Zipf(s) sampler over `n` items, Gries/rejection-inversion style.
#[derive(Debug, Clone)]
struct ZipfState {
    n: u64,
    theta: f64,
    alpha: f64,
    zeta_n: f64,
    eta: f64,
}

impl ZipfState {
    fn new(n: u64, theta: f64) -> Self {
        assert!(n > 0);
        assert!(theta > 0.0 && theta < 2.0 && (theta - 1.0).abs() > 1e-9);
        let zeta = |m: u64| -> f64 { (1..=m.min(10_000)).map(|i| 1.0 / (i as f64).powf(theta)).sum() };
        // For large n, approximate the zeta tail with the integral.
        let zeta_n = if n <= 10_000 {
            zeta(n)
        } else {
            zeta(10_000)
                + ((n as f64).powf(1.0 - theta) - 10_000f64.powf(1.0 - theta)) / (1.0 - theta)
        };
        let zeta2 = zeta(2.min(n));
        Self {
            n,
            theta,
            alpha: 1.0 / (1.0 - theta),
            zeta_n,
            eta: (1.0 - (2.0 / n as f64).powf(1.0 - theta)) / (1.0 - zeta2 / zeta_n),
        }
    }

    /// Sample a 0-based rank (0 = hottest).
    fn sample(&mut self, rng: &mut Rng) -> u64 {
        // Classic YCSB-style approximation.
        let u: f64 = rng.gen_f64();
        let uz = u * self.zeta_n;
        if uz < 1.0 {
            return 0;
        }
        if uz < 1.0 + 0.5f64.powf(self.theta) {
            return 1;
        }
        let rank = (self.n as f64 * (self.eta * u - self.eta + 1.0).powf(self.alpha)) as u64;
        rank.min(self.n - 1)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::GIB;

    fn region() -> MemRegion {
        MemRegion::new(GIB, 2 * GIB)
    }

    #[test]
    fn uniform_stays_in_region_and_line_aligned() {
        let r = region();
        let mut s = Stream::new(Pattern::Uniform(r), 1);
        for _ in 0..10_000 {
            let a = s.next_addr();
            assert!(r.contains(a));
            assert_eq!(a % LINE_BYTES, 0);
        }
    }

    #[test]
    fn uniform_covers_region_roughly_evenly() {
        let r = MemRegion::new(0, 128 * LINE_BYTES);
        let mut s = Stream::new(Pattern::Uniform(r), 2);
        let mut counts = vec![0u32; 128];
        for _ in 0..128_000 {
            counts[(s.next_addr() / LINE_BYTES) as usize] += 1;
        }
        let (min, max) = (counts.iter().min().unwrap(), counts.iter().max().unwrap());
        assert!(*min > 800 && *max < 1200, "min={min} max={max}");
    }

    #[test]
    fn sequential_wraps() {
        let r = MemRegion::new(0, 4 * LINE_BYTES);
        let mut s = Stream::new(Pattern::Sequential(r), 0);
        let seq: Vec<u64> = (0..6).map(|_| s.next_addr() / LINE_BYTES).collect();
        assert_eq!(seq, vec![0, 1, 2, 3, 0, 1]);
    }

    #[test]
    fn strided_pattern() {
        let r = MemRegion::new(0, 8 * LINE_BYTES);
        let mut s = Stream::new(
            Pattern::Strided {
                region: r,
                stride_lines: 3,
            },
            0,
        );
        let seq: Vec<u64> = (0..4).map(|_| s.next_addr() / LINE_BYTES).collect();
        assert_eq!(seq, vec![0, 3, 6, 1]);
    }

    #[test]
    fn zipf_is_skewed_and_in_region() {
        let r = MemRegion::new(0, 1024 * LINE_BYTES);
        let mut s = Stream::new(
            Pattern::Zipf {
                region: r,
                theta: 0.99,
            },
            3,
        );
        let mut counts = std::collections::HashMap::new();
        for _ in 0..50_000 {
            let a = s.next_addr();
            assert!(r.contains(a));
            *counts.entry(a).or_insert(0u32) += 1;
        }
        let mut freq: Vec<u32> = counts.values().copied().collect();
        freq.sort_unstable_by(|a, b| b.cmp(a));
        // Heavy skew: hottest line way above uniform expectation (~49) and
        // the top-16 lines carry a large share of all accesses.
        assert!(freq[0] > 1000, "max={}", freq[0]);
        let top16: u32 = freq.iter().take(16).sum();
        assert!(top16 > 50_000 / 3, "top16={top16}");
    }

    #[test]
    fn streams_are_deterministic_per_seed() {
        let r = region();
        let mut a = Stream::new(Pattern::Uniform(r), 9);
        let mut b = Stream::new(Pattern::Uniform(r), 9);
        let mut c = Stream::new(Pattern::Uniform(r), 10);
        let va: Vec<u64> = (0..100).map(|_| a.next_addr()).collect();
        let vb: Vec<u64> = (0..100).map(|_| b.next_addr()).collect();
        let vc: Vec<u64> = (0..100).map(|_| c.next_addr()).collect();
        assert_eq!(va, vb);
        assert_ne!(va, vc);
    }

    #[test]
    #[should_panic(expected = "at least one line")]
    fn empty_region_panics() {
        Stream::new(Pattern::Uniform(MemRegion::new(0, 0)), 0);
    }
}

//! Measurement results: what one simulated benchmark run reports.

use crate::sim::topology::GroupId;

/// Per-resource-group counters for one run.
#[derive(Debug, Clone, Default)]
pub struct GroupStats {
    pub group: GroupId,
    /// Active SMs of this group in the run.
    pub active_sms: usize,
    /// Counted (post-warmup) accesses issued by this group's SMs.
    pub accesses: u64,
    /// Group-TLB hits/misses over the whole run (warmup included).
    pub tlb_hits: u64,
    pub tlb_misses: u64,
    /// Real page walks and merged (MSHR-coalesced) misses.
    pub walks: u64,
    pub merged_walks: u64,
    /// Throughput attributable to this group, GB/s.
    pub gbps: f64,
}

impl GroupStats {
    pub fn tlb_hit_rate(&self) -> f64 {
        let total = self.tlb_hits + self.tlb_misses;
        if total == 0 {
            return 1.0;
        }
        self.tlb_hits as f64 / total as f64
    }
}

/// Result of one simulated benchmark run.
#[derive(Debug, Clone)]
pub struct Measurement {
    /// Aggregate read throughput over the measured window, GB/s
    /// (1 GB/s = 1e9 bytes/s, matching the paper's axes).
    pub gbps: f64,
    /// Measured (post-warmup) window length, ns.
    pub window_ns: f64,
    /// End-to-end simulated time, ns.
    pub sim_ns: f64,
    /// Accesses inside the measured window / in total.
    pub counted_accesses: u64,
    pub total_accesses: u64,
    /// Mean end-to-end access latency inside the window, ns.
    pub avg_latency_ns: f64,
    /// Aggregate group-TLB hit rate (all groups, whole run).
    pub tlb_hit_rate: f64,
    /// Aggregate per-SM uTLB hit rate.
    pub utlb_hit_rate: f64,
    /// HBM channel utilization inside the whole run (0..1).
    pub hbm_utilization: f64,
    pub per_group: Vec<GroupStats>,
}

impl Measurement {
    /// Total real page walks.
    pub fn walks(&self) -> u64 {
        self.per_group.iter().map(|g| g.walks).sum()
    }

    pub fn merged_walks(&self) -> u64 {
        self.per_group.iter().map(|g| g.merged_walks).sum()
    }

    /// Convenience: throughput of one group.
    pub fn group_gbps(&self, group: GroupId) -> f64 {
        self.per_group
            .iter()
            .find(|g| g.group == group)
            .map(|g| g.gbps)
            .unwrap_or(0.0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn hit_rate_handles_zero() {
        let g = GroupStats::default();
        assert_eq!(g.tlb_hit_rate(), 1.0);
    }

    #[test]
    fn hit_rate_math() {
        let g = GroupStats {
            tlb_hits: 75,
            tlb_misses: 25,
            ..Default::default()
        };
        assert!((g.tlb_hit_rate() - 0.75).abs() < 1e-12);
    }
}

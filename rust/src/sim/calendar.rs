//! Indexed calendar queue: the event core of the discrete-event engine.
//!
//! The engine keeps one pending completion event per in-flight access and
//! repeatedly extracts the globally earliest one.  A binary heap does that
//! in O(log n) per operation with poor locality (the seed engine's
//! profile was dominated by heap sift traffic at ~5k in-flight events).
//! This queue exploits the structure of those events instead:
//!
//! * every pushed completion time is `>=` the time of the event being
//!   popped (servers only ever schedule into the future), and
//! * the *spread* between now and the farthest pending completion is
//!   bounded by the worst queueing backlog (microseconds of simulated
//!   time), not by the length of the run.
//!
//! So events are binned into a ring of fixed-width time buckets covering a
//! sliding window `[cursor, cursor + nbuckets)` of bucket indices.  A push
//! appends to its bucket (O(1)); the rare event beyond the horizon goes to
//! an overflow list that is re-binned when the ring drains.  A pop sorts
//! the cursor bucket once when the cursor reaches it and then streams
//! events out of it in order — O(1) amortized, cache-friendly, and with
//! exactly one small sort per bucket.
//!
//! **Ordering contract:** pops are globally ordered by the full event
//! tuple `(completion, sm, issue_time)`, byte-for-byte the order a
//! `BinaryHeap<Reverse<...>>` of the same tuples produces.  Tests in
//! [`crate::sim::engine`] prove bit-identical `Measurement`s against the
//! reference heap engine.

use crate::sim::queue::Ps;

/// One pending completion: `(completion_time, sm_index, issue_time)`.
/// Tuple order *is* the priority order (lexicographic, like the heap).
pub type Event = (Ps, u32, Ps);

/// Default log2 of the bucket width in picoseconds.  4096 ps ~ 4 ns: on
/// the A100 preset one bucket holds a handful of HBM-channel service slots
/// (~3.1 ns each), so cursor-bucket sorts stay tiny while the ring spans a
/// 16 us horizon that covers even walker-saturated backlogs.
pub const DEFAULT_BUCKET_SHIFT: u32 = 12;

/// Default log2 of the bucket count (4096 buckets).
pub const DEFAULT_BUCKET_BITS: u32 = 12;

#[derive(Debug, Clone)]
pub struct CalendarQueue {
    /// Bucket width = `1 << shift` ps.
    shift: u32,
    /// `nbuckets - 1`; nbuckets is a power of two.
    mask: u64,
    /// The ring.  Slot for absolute bucket `b` is `b & mask`; each slot
    /// holds at most one absolute bucket because the live window is
    /// exactly `nbuckets` wide.
    buckets: Vec<Vec<Event>>,
    /// Absolute bucket index (`t >> shift`) the cursor stands on.
    cursor: u64,
    /// Cursor bucket contents, sorted ascending; drained via `current_pos`.
    current: Vec<Event>,
    current_pos: usize,
    /// Events with bucket beyond the ring window at push time, unordered.
    /// Re-binned into the ring before the cursor reaches their buckets.
    overflow: Vec<Event>,
    /// Smallest bucket of any overflow event (`u64::MAX` when empty).
    overflow_min: u64,
    len: usize,
}

impl CalendarQueue {
    /// A queue with the default geometry, pre-sized for `capacity` events.
    pub fn new(capacity: usize) -> Self {
        Self::with_geometry(DEFAULT_BUCKET_SHIFT, DEFAULT_BUCKET_BITS, capacity)
    }

    /// Explicit geometry: bucket width `1 << shift` ps, `1 << bits` buckets.
    pub fn with_geometry(shift: u32, bits: u32, capacity: usize) -> Self {
        let nbuckets = 1usize << bits;
        Self {
            shift,
            mask: (nbuckets - 1) as u64,
            buckets: vec![Vec::new(); nbuckets],
            cursor: 0,
            current: Vec::with_capacity(capacity.min(1024)),
            current_pos: 0,
            overflow: Vec::new(),
            overflow_min: u64::MAX,
            len: 0,
        }
    }

    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    #[inline]
    fn bucket_of(&self, t: Ps) -> u64 {
        t >> self.shift
    }

    /// Insert an event.  Events at or before the cursor's bucket must not
    /// be earlier than the last popped event (the engine guarantees
    /// completions are scheduled at or after "now"); debug builds assert.
    #[inline]
    pub fn push(&mut self, ev: Event) {
        let b = self.bucket_of(ev.0);
        self.len += 1;
        if b == self.cursor {
            // Same bucket the cursor is draining: keep it sorted.  The
            // insertion point is always at or after `current_pos` because
            // new completions are never earlier than the last pop.  `<=`
            // (insert after equals) so an event that ties exactly with an
            // already-drained tuple still lands ahead of the drain cursor
            // — equal tuples are indistinguishable, so order is preserved.
            let idx = self.current.partition_point(|e| e <= &ev);
            debug_assert!(idx >= self.current_pos, "event pushed into the past");
            self.current.insert(idx, ev);
        } else if b < self.cursor + self.buckets.len() as u64 {
            debug_assert!(b > self.cursor, "event pushed into the past");
            self.buckets[(b & self.mask) as usize].push(ev);
        } else {
            self.overflow_min = self.overflow_min.min(b);
            self.overflow.push(ev);
        }
    }

    /// Extract the globally earliest event (tuple order).
    #[inline]
    pub fn pop(&mut self) -> Option<Event> {
        loop {
            if self.current_pos < self.current.len() {
                let ev = self.current[self.current_pos];
                self.current_pos += 1;
                self.len -= 1;
                return Some(ev);
            }
            if self.len == 0 {
                return None;
            }
            self.advance();
        }
    }

    /// Move the cursor to the next non-empty bucket.  Overflow events are
    /// re-binned into the ring the moment the cursor reaches their bucket
    /// range (they were beyond the horizon at push time; the window has
    /// since slid forward), so the ring always holds every event the
    /// cursor could encounter next and pops stay globally ordered.
    fn advance(&mut self) {
        loop {
            // Ring empty (all remaining events in overflow)?  Jump the
            // cursor straight to the earliest overflow bucket instead of
            // scanning empty slots.
            if self.len == self.overflow.len() {
                debug_assert!(!self.overflow.is_empty());
                self.cursor = self.overflow_min;
                self.rebin_overflow();
                let slot = (self.cursor & self.mask) as usize;
                debug_assert!(!self.buckets[slot].is_empty());
                self.take_bucket(slot);
                return;
            }
            self.cursor += 1;
            // The cursor caught up with the earliest overflow event: pull
            // every overflow event now inside the window into the ring
            // before inspecting this bucket.
            if self.overflow_min <= self.cursor {
                self.rebin_overflow();
            }
            let slot = (self.cursor & self.mask) as usize;
            if !self.buckets[slot].is_empty() {
                self.take_bucket(slot);
                return;
            }
        }
    }

    /// Move overflow events whose bucket fits inside the current ring
    /// window `[cursor, cursor + nbuckets)` into the ring; recompute the
    /// overflow minimum for the remainder.
    fn rebin_overflow(&mut self) {
        let horizon = self.cursor + self.buckets.len() as u64;
        let mut new_min = u64::MAX;
        let mut i = 0;
        while i < self.overflow.len() {
            let b = self.bucket_of(self.overflow[i].0);
            if b < horizon {
                debug_assert!(b >= self.cursor);
                let ev = self.overflow.swap_remove(i);
                self.buckets[(b & self.mask) as usize].push(ev);
            } else {
                new_min = new_min.min(b);
                i += 1;
            }
        }
        self.overflow_min = new_min;
    }

    /// Swap a ring bucket into the cursor position and sort it once.  The
    /// spent `current` storage is recycled as the (empty) ring bucket.
    fn take_bucket(&mut self, slot: usize) {
        self.current.clear();
        std::mem::swap(&mut self.current, &mut self.buckets[slot]);
        self.current.sort_unstable();
        self.current_pos = 0;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;
    use std::cmp::Reverse;
    use std::collections::BinaryHeap;

    fn drain(q: &mut CalendarQueue) -> Vec<Event> {
        let mut out = Vec::new();
        while let Some(e) = q.pop() {
            out.push(e);
        }
        out
    }

    #[test]
    fn pops_in_tuple_order() {
        let mut q = CalendarQueue::new(16);
        q.push((5_000, 1, 10));
        q.push((1_000, 0, 0));
        q.push((5_000, 0, 3));
        q.push((5_000, 0, 2));
        q.push((3_000, 7, 1));
        let got = drain(&mut q);
        assert_eq!(
            got,
            vec![
                (1_000, 0, 0),
                (3_000, 7, 1),
                (5_000, 0, 2),
                (5_000, 0, 3),
                (5_000, 1, 10)
            ]
        );
        assert!(q.is_empty());
    }

    #[test]
    fn interleaved_push_pop_stays_ordered() {
        // The engine's pattern: pop an event at t, push a new one >= t.
        let mut q = CalendarQueue::new(64);
        for i in 0..32u32 {
            q.push((1_000 + i as u64 * 37, i, 0));
        }
        let mut last = 0;
        let mut rng = Rng::seed_from_u64(1);
        let mut pops = 0;
        while let Some((t, sm, _)) = q.pop() {
            assert!(t >= last, "pop went backwards: {t} < {last}");
            last = t;
            pops += 1;
            if pops < 10_000 {
                // Reschedule "the SM" with a completion in the near or far
                // future (occasionally way past the ring horizon).
                let delta = if rng.gen_bool(0.01) {
                    rng.gen_range(1 << 28) + 1
                } else {
                    rng.gen_range(200_000) + 1
                };
                q.push((t + delta, sm, t));
            }
        }
        assert_eq!(pops, 10_000 + 31);
    }

    #[test]
    fn same_bucket_push_while_draining() {
        let mut q = CalendarQueue::with_geometry(12, 4, 8);
        q.push((100, 0, 0));
        q.push((200, 1, 0));
        assert_eq!(q.pop(), Some((100, 0, 0)));
        // 150 lands in the bucket currently being drained, between the
        // popped 100 and the pending 200.
        q.push((150, 2, 0));
        assert_eq!(q.pop(), Some((150, 2, 0)));
        assert_eq!(q.pop(), Some((200, 1, 0)));
        assert_eq!(q.pop(), None);
    }

    #[test]
    fn overflow_rollover_rebins_correctly() {
        // Tiny ring (16 buckets of 4096 ps = 64 ns horizon) forces heavy
        // overflow traffic and several rollovers.
        let mut q = CalendarQueue::with_geometry(12, 4, 8);
        let mut expect = Vec::new();
        let mut rng = Rng::seed_from_u64(9);
        for i in 0..500u32 {
            let t = rng.gen_range(50_000_000);
            q.push((t, i, 0));
            expect.push((t, i, 0u64));
        }
        expect.sort_unstable();
        assert_eq!(drain(&mut q), expect);
    }

    #[test]
    fn empty_queue_behaves() {
        let mut q = CalendarQueue::new(0);
        assert!(q.is_empty());
        assert_eq!(q.pop(), None);
        q.push((7, 0, 0));
        assert_eq!(q.len(), 1);
        assert_eq!(q.pop(), Some((7, 0, 0)));
        assert_eq!(q.pop(), None);
    }

    #[test]
    fn matches_binary_heap_on_random_workload() {
        // Exact-equivalence against the heap on the engine's push/pop
        // discipline, including ties on the completion time.
        let mut q = CalendarQueue::with_geometry(10, 6, 64);
        let mut h: BinaryHeap<Reverse<Event>> = BinaryHeap::new();
        let mut rng = Rng::seed_from_u64(42);
        for i in 0..64u32 {
            let e = (rng.gen_range(10_000), i, rng.gen_range(100));
            q.push(e);
            h.push(Reverse(e));
        }
        for step in 0..50_000 {
            let a = q.pop();
            let b = h.pop().map(|Reverse(e)| e);
            assert_eq!(a, b, "diverged at step {step}");
            let Some((t, sm, _)) = a else { break };
            if step < 49_000 {
                // Quantize to the bucket width sometimes to force ties.
                let mut nt = t + rng.gen_range(1 << 20) + 1;
                if rng.gen_bool(0.3) {
                    nt &= !((1 << 10) - 1);
                    // Strictly-future completions only (the engine's servers
                    // always add positive service time).
                    nt = nt.max(t + 1);
                }
                let e = (nt, sm, t);
                q.push(e);
                h.push(Reverse(e));
            }
        }
        assert!(q.is_empty() == h.is_empty());
    }
}

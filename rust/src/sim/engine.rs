//! The discrete-event engine: simulate the paper's benchmark kernel on the
//! modelled memory hierarchy and measure throughput.
//!
//! Model of one access (one warp's coalesced 128 B read):
//!
//! ```text
//! SM issues ──► uTLB ──hit──────────────────────────┐
//!                │ miss                             │
//!                ▼                                  │
//!            group TLB ──hit (translation ready)──► │
//!                │ miss                             ▼
//!                ▼                             group port ─► GPC hub ─► HBM channel ─► data back
//!            walker pool (k-server, MSHR merge)     ▲
//!                └── translation ready ─────────────┘
//! ```
//!
//! Each SM keeps `cfg.sm.outstanding` accesses in flight (one per resident
//! warp); when one completes the SM issues the next, rate-limited by the
//! issue interval.  Events are processed in global time order, so every
//! FIFO server sees arrivals in nondecreasing time order (the virtual-clock
//! queue formulation in [`queue`] is then exact).
//!
//! Approximation (documented): a TLB miss installs its translation at
//! lookup time while the access itself waits for the walk.  A concurrent
//! access to the *same* page that hits on the young entry consults the
//! walker's pending table and waits for the same walk, so hit-under-miss
//! timing stays correct; the entry merely becomes evictable one walk-time
//! early, which is negligible at TLB capacities of interest.

use std::cmp::Reverse;
use std::collections::{BinaryHeap, HashMap};

use crate::config::MachineConfig;
use crate::sim::access::{Pattern, Stream};
use crate::sim::hbm::Hbm;
use crate::sim::pages::{line_of, page_of, page_shift};
use crate::sim::port::{GpcHub, GroupPort};
use crate::sim::queue::{ns_to_ps, ps_to_ns, Ps};
use crate::sim::stats::{GroupStats, Measurement};
use crate::sim::tlb::{FullyAssocTlb, SetAssocTlb};
use crate::sim::topology::{SmId, Topology};
use crate::sim::walker::WalkerPool;

/// Which SMs run, and what each reads.
#[derive(Debug, Clone)]
pub struct SmAssignment {
    pub smid: SmId,
    pub pattern: Pattern,
}

/// One benchmark run.
#[derive(Debug, Clone)]
pub struct MeasurementSpec {
    pub assignments: Vec<SmAssignment>,
    /// Accesses each SM issues (warmup included).
    pub accesses_per_sm: u64,
    /// Leading fraction of each SM's accesses excluded from the measured
    /// window (TLB warmup).
    pub warmup_fraction: f64,
    /// Transaction size in bytes (the paper's default unit is 128).
    pub txn_bytes: u64,
    pub seed: u64,
}

impl MeasurementSpec {
    /// The common case: `sms` all reading `pattern`-shaped streams.
    pub fn uniform_all(sms: &[SmId], pattern: Pattern, accesses_per_sm: u64, seed: u64) -> Self {
        Self {
            assignments: sms
                .iter()
                .map(|&smid| SmAssignment {
                    smid,
                    pattern: pattern.clone(),
                })
                .collect(),
            accesses_per_sm,
            warmup_fraction: 0.25,
            txn_bytes: crate::config::LINE_BYTES,
            seed,
        }
    }
}

/// The simulated device.
#[derive(Debug, Clone)]
pub struct Machine {
    cfg: MachineConfig,
    topo: Topology,
    /// Memoized pre-warmed group-TLB states, keyed by the group's region
    /// set.  Pre-warming inserts up to `entries` pages (65 k operations for
    /// the A100 preset) which dominates short probe runs; cloning a warmed
    /// tag array is a ~0.5 MB memcpy instead (EXPERIMENTS.md §Perf L3
    /// iteration 3).  Shared across clones so parallel sweeps hit it.
    warm_cache: std::sync::Arc<std::sync::Mutex<HashMap<Vec<(u64, u64)>, SetAssocTlb>>>,
}

struct SmState {
    stream: Stream,
    utlb: FullyAssocTlb,
    group_idx: usize,
    gpc_idx: usize,
    issued: u64,
    completed: u64,
    warmup: u64,
    last_issue: Ps,
    counted_bytes: u64,
    counted_accesses: u64,
    latency_sum: Ps,
    utlb_hits: u64,
    utlb_lookups: u64,
}

struct GroupState {
    group: usize,
    tlb: SetAssocTlb,
    walkers: WalkerPool,
    port: GroupPort,
    active_sms: usize,
    counted_bytes: u64,
    counted_accesses: u64,
}

impl Machine {
    pub fn new(cfg: MachineConfig) -> Result<Self, String> {
        cfg.validate()?;
        let topo = Topology::build(&cfg.topology);
        Ok(Self {
            cfg,
            topo,
            warm_cache: Default::default(),
        })
    }

    pub fn config(&self) -> &MachineConfig {
        &self.cfg
    }

    pub fn topology(&self) -> &Topology {
        &self.topo
    }

    /// Run one benchmark measurement.
    pub fn run(&self, spec: &MeasurementSpec) -> Measurement {
        assert!(!spec.assignments.is_empty(), "no SMs assigned");
        assert!(spec.accesses_per_sm > 0);
        let shift = page_shift(self.cfg.tlb.page_bytes);
        let hit_ps = ns_to_ps(self.cfg.tlb.hit_ns);
        let walk_svc = ns_to_ps(self.cfg.tlb.walk_ns);
        let issue_iv = ns_to_ps(self.cfg.sm.issue_interval_ns);
        let outstanding = self.cfg.sm.outstanding as u64;
        let txn = spec.txn_bytes;

        // --- Build run-local component state -----------------------------
        // Map active groups/GPCs to dense indices (GroupStates are created
        // below, once the pre-warmed TLB content is known, to avoid a
        // throwaway 0.5 MB tag-array allocation per group).
        let mut group_idx_of = vec![usize::MAX; self.topo.group_count()];
        let mut group_ids: Vec<usize> = Vec::new();
        let mut group_active: Vec<usize> = Vec::new();
        let n_gpcs = self.cfg.topology.enabled_gpcs;
        let mut gpc_active_groups = vec![std::collections::HashSet::new(); n_gpcs];
        for a in &spec.assignments {
            let g = self.topo.group_of(a.smid);
            if group_idx_of[g] == usize::MAX {
                group_idx_of[g] = group_ids.len();
                group_ids.push(g);
                group_active.push(0);
            }
            group_active[group_idx_of[g]] += 1;
            gpc_active_groups[self.topo.gpc_of_group(g)].insert(g);
        }
        // Pre-warm each group TLB to steady state.  The paper's benchmark
        // measures long steady-state runs (billions of accesses); simulating
        // the cold-fill of a 32768-entry TLB would waste all our simulated
        // accesses on compulsory misses.  Under LRU + uniform random access
        // over N pages with capacity C, the steady-state content is C
        // uniformly-drawn pages, so pre-inserting a uniform page sample (or
        // the whole working set when it fits) starts the run at its
        // asymptotic hit rate.
        let mut group_regions: Vec<std::collections::BTreeMap<(u64, u64), u64>> =
            vec![Default::default(); group_ids.len()];
        for a in &spec.assignments {
            let g = group_idx_of[self.topo.group_of(a.smid)];
            let r = a.pattern.region();
            group_regions[g]
                .insert((r.base, r.len), r.pages(self.cfg.tlb.page_bytes));
        }
        let cap = self.cfg.tlb.entries as u64;
        let mut groups: Vec<GroupState> = Vec::with_capacity(group_ids.len());
        for (gi, regions) in group_regions.iter().enumerate() {
            let key: Vec<(u64, u64)> = regions.keys().copied().collect();
            // Memoized warm state: build once per distinct region set, then
            // clone the tag arrays (fast memcpy) for every later run.
            let cached = self.warm_cache.lock().unwrap().get(&key).cloned();
            let warmed = match cached {
                Some(t) => t,
                None => {
                    let mut t =
                        SetAssocTlb::new(self.cfg.tlb.entries, self.cfg.tlb.associativity);
                    let total: u64 = regions.values().sum();
                    for (&(base, _len), &pages) in regions {
                        let first = base >> shift;
                        // Insert the whole working set when it fits;
                        // otherwise a stride-sampled, capacity-proportional
                        // share per region.
                        let take = if total <= cap {
                            pages
                        } else {
                            (cap * pages / total).max(1)
                        };
                        for k in 0..take {
                            let p = first + (k * pages) / take;
                            t.insert(p);
                        }
                    }
                    t.reset_stats();
                    self.warm_cache
                        .lock()
                        .unwrap()
                        .insert(key, t.clone());
                    t
                }
            };
            groups.push(GroupState {
                group: group_ids[gi],
                tlb: warmed,
                walkers: WalkerPool::new(self.cfg.tlb.walkers_per_group, walk_svc),
                port: GroupPort::new(&self.cfg.memory, txn),
                active_sms: group_active[gi],
                counted_bytes: 0,
                counted_accesses: 0,
            });
        }

        let mut hubs: Vec<GpcHub> = (0..n_gpcs)
            .map(|gpc| GpcHub::new(&self.cfg.memory, txn, gpc_active_groups[gpc].len() >= 2))
            .collect();
        let mut hbm = Hbm::new(&self.cfg.memory, txn);

        let warmup = ((spec.accesses_per_sm as f64) * spec.warmup_fraction) as u64;
        let mut sms: Vec<SmState> = spec
            .assignments
            .iter()
            .enumerate()
            .map(|(i, a)| {
                let g = self.topo.group_of(a.smid);
                SmState {
                    stream: Stream::new(
                        a.pattern.clone(),
                        spec.seed
                            .wrapping_mul(0x9E37_79B9_7F4A_7C15)
                            .wrapping_add(((a.smid as u64) << 20) | i as u64),
                    ),
                    utlb: FullyAssocTlb::new(self.cfg.tlb.utlb_entries),
                    group_idx: group_idx_of[g],
                    gpc_idx: self.topo.gpc_of_group(g),
                    issued: 0,
                    completed: 0,
                    warmup,
                    last_issue: 0,
                    counted_bytes: 0,
                    counted_accesses: 0,
                    latency_sum: 0,
                    utlb_hits: 0,
                    utlb_lookups: 0,
                }
            })
            .collect();

        // --- Event loop ---------------------------------------------------
        // One heap event per access: an access is fully routed through the
        // translation + data path *at issue time* (the virtual-clock
        // servers absorb out-of-order arrivals), and the heap only orders
        // completions; the SM issues its next access when one completes.
        // This is 2x fewer heap operations than a staged issue/complete
        // loop with identical results (EXPERIMENTS.md §Perf L3).
        let issue =
            |sms: &mut Vec<SmState>,
             groups: &mut Vec<GroupState>,
             hubs: &mut Vec<GpcHub>,
             hbm: &mut Hbm,
             sm: u32,
             t: Ps|
             -> (Ps, Ps) {
                let s = &mut sms[sm as usize];
                let t_issue = t.max(s.last_issue + issue_iv);
                s.last_issue = t_issue;
                s.issued += 1;

                let addr = s.stream.next_addr();
                let page = page_of(addr, shift);
                let line = line_of(addr);
                let gs = &mut groups[s.group_idx];

                // Translation.
                s.utlb_lookups += 1;
                let mut ready = t_issue;
                if s.utlb.access(page) {
                    s.utlb_hits += 1;
                    // Translation cached SM-locally: no group-TLB trip.
                } else if gs.tlb.lookup(page) {
                    ready = t_issue + hit_ps;
                    // Hit-under-miss: if a walk for this page is still in
                    // flight, the translation is not actually ready until
                    // it lands.
                    ready = ready.max(gs.walkers.pending_completion(page).unwrap_or(0));
                } else {
                    let done = gs.walkers.walk(t_issue + hit_ps, page);
                    gs.tlb.insert(page);
                    ready = done;
                }

                // Data path.
                let after_port = gs.port.pass(ready);
                let after_hub = hubs[s.gpc_idx].pass(after_port);
                let done = hbm.access(after_hub, line);
                (done, t_issue)
            };

        // Heap of (completion, sm, issue_time).
        let mut heap: BinaryHeap<Reverse<(Ps, u32, Ps)>> = BinaryHeap::with_capacity(
            spec.assignments.len() * outstanding as usize + 1,
        );
        // Stagger initial slot issues by the issue interval, slot-major so
        // the shared servers see globally nondecreasing arrival times (the
        // virtual-clock FIFO contract; SM-major seeding would present each
        // later SM's t=0 arrivals *after* the previous SM's t=33 ns ones and
        // conjure a phantom standing backlog on near-saturated servers).
        for k in 0..outstanding.min(spec.accesses_per_sm) {
            for i in 0..spec.assignments.len() as u32 {
                let (done, t_issue) =
                    issue(&mut sms, &mut groups, &mut hubs, &mut hbm, i, k * issue_iv);
                heap.push(Reverse((done, i, t_issue)));
            }
        }

        let mut meas_start: Ps = Ps::MAX;
        let mut meas_end: Ps = 0;
        let mut sim_end: Ps = 0;

        while let Some(Reverse((t, sm, issued))) = heap.pop() {
            let s = &mut sms[sm as usize];
            s.completed += 1;
            sim_end = sim_end.max(t);
            if s.completed > s.warmup {
                s.counted_bytes += txn;
                s.counted_accesses += 1;
                s.latency_sum += t - issued;
                groups[s.group_idx].counted_bytes += txn;
                groups[s.group_idx].counted_accesses += 1;
                meas_start = meas_start.min(issued);
                meas_end = meas_end.max(t);
            }
            if s.issued < spec.accesses_per_sm {
                let (done, t_issue) = issue(&mut sms, &mut groups, &mut hubs, &mut hbm, sm, t);
                heap.push(Reverse((done, sm, t_issue)));
            }
        }

        // --- Aggregate ----------------------------------------------------
        let window = meas_end.saturating_sub(meas_start).max(1);
        let counted_bytes: u64 = sms.iter().map(|s| s.counted_bytes).sum();
        let counted_accesses: u64 = sms.iter().map(|s| s.counted_accesses).sum();
        let total_accesses: u64 = sms.iter().map(|s| s.issued).sum();
        let latency_sum: Ps = sms.iter().map(|s| s.latency_sum).sum();
        let utlb_hits: u64 = sms.iter().map(|s| s.utlb_hits).sum();
        let utlb_lookups: u64 = sms.iter().map(|s| s.utlb_lookups).sum();
        let window_s = window as f64 * 1e-12;
        let gbps = counted_bytes as f64 / 1e9 / window_s;

        let tlb_hits: u64 = groups.iter().map(|g| g.tlb.hits()).sum();
        let tlb_misses: u64 = groups.iter().map(|g| g.tlb.misses()).sum();
        let per_group = groups
            .iter()
            .map(|g| GroupStats {
                group: g.group,
                active_sms: g.active_sms,
                accesses: g.counted_accesses,
                tlb_hits: g.tlb.hits(),
                tlb_misses: g.tlb.misses(),
                walks: g.walkers.walks(),
                merged_walks: g.walkers.merged(),
                gbps: g.counted_bytes as f64 / 1e9 / window_s,
            })
            .collect();

        Measurement {
            gbps,
            window_ns: ps_to_ns(window),
            sim_ns: ps_to_ns(sim_end),
            counted_accesses,
            total_accesses,
            avg_latency_ns: if counted_accesses > 0 {
                ps_to_ns(latency_sum) / counted_accesses as f64
            } else {
                0.0
            },
            tlb_hit_rate: if tlb_hits + tlb_misses > 0 {
                tlb_hits as f64 / (tlb_hits + tlb_misses) as f64
            } else {
                1.0
            },
            utlb_hit_rate: if utlb_lookups > 0 {
                utlb_hits as f64 / utlb_lookups as f64
            } else {
                0.0
            },
            hbm_utilization: hbm.busy_ps() as f64
                / (hbm.channel_count() as f64 * sim_end.max(1) as f64),
            per_group,
        }
    }
}

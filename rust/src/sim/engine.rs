//! The discrete-event engine: simulate the paper's benchmark kernel on the
//! modelled memory hierarchy and measure throughput.
//!
//! Model of one access (one warp's coalesced 128 B read):
//!
//! ```text
//! SM issues ──► uTLB ──hit──────────────────────────┐
//!                │ miss                             │
//!                ▼                                  │
//!            group TLB ──hit (translation ready)──► │
//!                │ miss                             ▼
//!                ▼                             group port ─► GPC hub ─► HBM channel ─► data back
//!            walker pool (k-server, MSHR merge)     ▲
//!                └── translation ready ─────────────┘
//! ```
//!
//! Each SM keeps `cfg.sm.outstanding` accesses in flight (one per resident
//! warp); when one completes the SM issues the next, rate-limited by the
//! issue interval.  Events are processed in global time order, so every
//! FIFO server sees arrivals in nondecreasing time order (the virtual-clock
//! queue formulation in [`queue`] is then exact).
//!
//! Approximation (documented): a TLB miss installs its translation at
//! lookup time while the access itself waits for the walk.  A concurrent
//! access to the *same* page that hits on the young entry consults the
//! walker's pending table and waits for the same walk, so hit-under-miss
//! timing stays correct; the entry merely becomes evictable one walk-time
//! early, which is negligible at TLB capacities of interest.
//!
//! ## Hot-path structure (EXPERIMENTS.md §Perf L3)
//!
//! The event core is an indexed [`CalendarQueue`](crate::sim::calendar)
//! (O(1) amortized) instead of a binary heap; per-SM hot fields live in
//! struct-of-arrays form inside [`RunState`]; the walker pending table is
//! open-addressed; and parallel sweeps go through [`Machine::run_many`],
//! which shares pre-warmed TLB images through a sharded read-mostly cache.
//! The seed's heap-driven loop survives verbatim as
//! [`Machine::run_reference_heap`] — the oracle that the optimized engine
//! must match bit-for-bit (see the equivalence tests below) and the
//! baseline that `benches/engine_throughput.rs` measures speedup against.

use std::collections::HashMap;
use std::sync::{Arc, RwLock};

use crate::config::MachineConfig;
use crate::sim::access::{Pattern, Stream};
use crate::sim::calendar::CalendarQueue;
use crate::sim::hbm::Hbm;
use crate::sim::pages::{line_of, page_of, page_shift};
use crate::sim::port::{GpcHub, GroupPort};
use crate::sim::queue::{ns_to_ps, ps_to_ns, Ps};
use crate::sim::stats::{GroupStats, Measurement};
use crate::sim::tlb::{FullyAssocTlb, SetAssocTlb};
use crate::sim::topology::{SmId, Topology};
use crate::sim::walker::WalkerPool;

/// Which SMs run, and what each reads.
#[derive(Debug, Clone)]
pub struct SmAssignment {
    pub smid: SmId,
    pub pattern: Pattern,
}

/// One benchmark run.
#[derive(Debug, Clone)]
pub struct MeasurementSpec {
    pub assignments: Vec<SmAssignment>,
    /// Accesses each SM issues (warmup included).
    pub accesses_per_sm: u64,
    /// Leading fraction of each SM's accesses excluded from the measured
    /// window (TLB warmup).
    pub warmup_fraction: f64,
    /// Transaction size in bytes (the paper's default unit is 128).
    pub txn_bytes: u64,
    pub seed: u64,
}

impl MeasurementSpec {
    /// The common case: `sms` all reading `pattern`-shaped streams.
    pub fn uniform_all(sms: &[SmId], pattern: Pattern, accesses_per_sm: u64, seed: u64) -> Self {
        Self {
            assignments: sms
                .iter()
                .map(|&smid| SmAssignment {
                    smid,
                    pattern: pattern.clone(),
                })
                .collect(),
            accesses_per_sm,
            warmup_fraction: 0.25,
            txn_bytes: crate::config::LINE_BYTES,
            seed,
        }
    }
}

/// Memoized pre-warmed group-TLB states, keyed by the group's region set.
///
/// Pre-warming inserts up to `entries` pages (65 k operations for the A100
/// preset) which dominates short probe runs; cloning a warmed tag array is
/// a ~0.5 MB memcpy instead (EXPERIMENTS.md §Perf L3 iteration 3).  The
/// cache is sharded by key hash behind `RwLock`s so the read-mostly steady
/// state of a [`Machine::run_many`] sweep (thousands of lookups, a handful
/// of builds) never serializes on one mutex.
const WARM_SHARDS: usize = 8;

#[derive(Debug)]
struct WarmCache {
    shards: [RwLock<HashMap<Vec<(u64, u64)>, SetAssocTlb>>; WARM_SHARDS],
}

impl Default for WarmCache {
    fn default() -> Self {
        Self {
            shards: std::array::from_fn(|_| RwLock::new(HashMap::new())),
        }
    }
}

impl WarmCache {
    fn shard_of(key: &[(u64, u64)]) -> usize {
        // FNV-1a over the region descriptors.
        let mut h = 0xcbf2_9ce4_8422_2325u64;
        for &(a, b) in key {
            for w in [a, b] {
                h ^= w;
                h = h.wrapping_mul(0x100_0000_01b3);
            }
        }
        (h >> 32) as usize % WARM_SHARDS
    }

    /// Fetch a warmed TLB image, building (and publishing) it on miss.
    /// Builds run outside any lock; a racing duplicate build produces an
    /// identical image, so either insert order yields the same content.
    fn get_or_build(
        &self,
        key: Vec<(u64, u64)>,
        build: impl FnOnce() -> SetAssocTlb,
    ) -> SetAssocTlb {
        let shard = &self.shards[Self::shard_of(&key)];
        if let Some(t) = shard.read().unwrap().get(&key) {
            return t.clone();
        }
        let t = build();
        shard.write().unwrap().insert(key, t.clone());
        t
    }
}

/// The simulated device.
#[derive(Debug, Clone)]
pub struct Machine {
    cfg: MachineConfig,
    topo: Topology,
    /// Shared across clones so parallel sweeps hit the same warm images.
    warm_cache: Arc<WarmCache>,
}

/// Per-SM hot state in struct-of-arrays form: the issue path touches
/// `last_issue`/`issued`/`stream`/`utlb`/`group_idx`/`gpc_idx`, the
/// completion path touches the counters — each loop streams over dense
/// same-kind arrays instead of striding across a 200-byte struct.
struct SmArrays {
    stream: Vec<Stream>,
    utlb: Vec<FullyAssocTlb>,
    group_idx: Vec<u32>,
    gpc_idx: Vec<u32>,
    last_issue: Vec<Ps>,
    issued: Vec<u64>,
    completed: Vec<u64>,
    counted_accesses: Vec<u64>,
    latency_sum: Vec<Ps>,
    utlb_hits: Vec<u64>,
    utlb_lookups: Vec<u64>,
}

struct GroupState {
    group: usize,
    tlb: SetAssocTlb,
    walkers: WalkerPool,
    port: GroupPort,
    active_sms: usize,
    counted_accesses: u64,
}

/// Everything one simulation run mutates, borrowed exactly once by the
/// event loop (the seed engine threaded five `&mut` params through a
/// closure instead).
struct RunState {
    shift: u32,
    hit_ps: Ps,
    issue_iv: Ps,
    sms: SmArrays,
    groups: Vec<GroupState>,
    hubs: Vec<GpcHub>,
    hbm: Hbm,
}

impl RunState {
    /// Issue one access for `sm` at (no earlier than) `t`: route it through
    /// translation and the data path at issue time, returning
    /// `(completion, issue_time)`.  The virtual-clock servers absorb
    /// out-of-order arrivals, so one event per access suffices — 2x fewer
    /// queue operations than a staged issue/complete loop with identical
    /// results (EXPERIMENTS.md §Perf L3).
    #[inline]
    fn issue(&mut self, sm: u32, t: Ps) -> (Ps, Ps) {
        let i = sm as usize;
        let t_issue = t.max(self.sms.last_issue[i] + self.issue_iv);
        self.sms.last_issue[i] = t_issue;
        self.sms.issued[i] += 1;

        let addr = self.sms.stream[i].next_addr();
        let page = page_of(addr, self.shift);
        let line = line_of(addr);
        let gi = self.sms.group_idx[i] as usize;
        let gs = &mut self.groups[gi];

        // Translation.
        self.sms.utlb_lookups[i] += 1;
        let mut ready = t_issue;
        if self.sms.utlb[i].access(page) {
            self.sms.utlb_hits[i] += 1;
            // Translation cached SM-locally: no group-TLB trip.
        } else if gs.tlb.lookup(page) {
            ready = t_issue + self.hit_ps;
            // Hit-under-miss: if a walk for this page is still in flight,
            // the translation is not actually ready until it lands.
            ready = ready.max(gs.walkers.pending_completion(page).unwrap_or(0));
        } else {
            let done = gs.walkers.walk(t_issue + self.hit_ps, page);
            gs.tlb.insert(page);
            ready = done;
        }

        // Data path.
        let after_port = gs.port.pass(ready);
        let after_hub = self.hubs[self.sms.gpc_idx[i] as usize].pass(after_port);
        let done = self.hbm.access(after_hub, line);
        (done, t_issue)
    }
}

impl Machine {
    pub fn new(cfg: MachineConfig) -> Result<Self, String> {
        cfg.validate()?;
        let topo = Topology::build(&cfg.topology);
        Ok(Self {
            cfg,
            topo,
            warm_cache: Default::default(),
        })
    }

    pub fn config(&self) -> &MachineConfig {
        &self.cfg
    }

    pub fn topology(&self) -> &Topology {
        &self.topo
    }

    /// Build the run-local component state for one spec.
    fn build_run_state(&self, spec: &MeasurementSpec) -> RunState {
        assert!(!spec.assignments.is_empty(), "no SMs assigned");
        assert!(spec.accesses_per_sm > 0);
        let shift = page_shift(self.cfg.tlb.page_bytes);
        let walk_svc = ns_to_ps(self.cfg.tlb.walk_ns);
        let txn = spec.txn_bytes;

        // Map active groups/GPCs to dense indices (GroupStates are created
        // below, once the pre-warmed TLB content is known, to avoid a
        // throwaway 0.5 MB tag-array allocation per group).
        let mut group_idx_of = vec![usize::MAX; self.topo.group_count()];
        let mut group_ids: Vec<usize> = Vec::new();
        let mut group_active: Vec<usize> = Vec::new();
        let n_gpcs = self.cfg.topology.enabled_gpcs;
        let mut gpc_active_groups = vec![std::collections::HashSet::new(); n_gpcs];
        for a in &spec.assignments {
            let g = self.topo.group_of(a.smid);
            if group_idx_of[g] == usize::MAX {
                group_idx_of[g] = group_ids.len();
                group_ids.push(g);
                group_active.push(0);
            }
            group_active[group_idx_of[g]] += 1;
            gpc_active_groups[self.topo.gpc_of_group(g)].insert(g);
        }
        // Pre-warm each group TLB to steady state.  The paper's benchmark
        // measures long steady-state runs (billions of accesses); simulating
        // the cold-fill of a 32768-entry TLB would waste all our simulated
        // accesses on compulsory misses.  Under LRU + uniform random access
        // over N pages with capacity C, the steady-state content is C
        // uniformly-drawn pages, so pre-inserting a uniform page sample (or
        // the whole working set when it fits) starts the run at its
        // asymptotic hit rate.
        let mut group_regions: Vec<std::collections::BTreeMap<(u64, u64), u64>> =
            vec![Default::default(); group_ids.len()];
        for a in &spec.assignments {
            let g = group_idx_of[self.topo.group_of(a.smid)];
            let r = a.pattern.region();
            group_regions[g].insert((r.base, r.len), r.pages(self.cfg.tlb.page_bytes));
        }
        let cap = self.cfg.tlb.entries as u64;
        let mut groups: Vec<GroupState> = Vec::with_capacity(group_ids.len());
        for (gi, regions) in group_regions.iter().enumerate() {
            let key: Vec<(u64, u64)> = regions.keys().copied().collect();
            // Memoized warm state: build once per distinct region set, then
            // clone the tag arrays (fast memcpy) for every later run.
            let warmed = self.warm_cache.get_or_build(key, || {
                let mut t = SetAssocTlb::new(self.cfg.tlb.entries, self.cfg.tlb.associativity);
                let total: u64 = regions.values().sum();
                for (&(base, _len), &pages) in regions {
                    let first = base >> shift;
                    // Insert the whole working set when it fits; otherwise a
                    // stride-sampled, capacity-proportional share per region.
                    let take = if total <= cap {
                        pages
                    } else {
                        (cap * pages / total).max(1)
                    };
                    for k in 0..take {
                        let p = first + (k * pages) / take;
                        t.insert(p);
                    }
                }
                t.reset_stats();
                t
            });
            groups.push(GroupState {
                group: group_ids[gi],
                tlb: warmed,
                walkers: WalkerPool::new(self.cfg.tlb.walkers_per_group, walk_svc),
                port: GroupPort::new(&self.cfg.memory, txn),
                active_sms: group_active[gi],
                counted_accesses: 0,
            });
        }

        let hubs: Vec<GpcHub> = (0..n_gpcs)
            .map(|gpc| GpcHub::new(&self.cfg.memory, txn, gpc_active_groups[gpc].len() >= 2))
            .collect();
        let hbm = Hbm::new(&self.cfg.memory, txn);

        let n = spec.assignments.len();
        let mut sms = SmArrays {
            stream: Vec::with_capacity(n),
            utlb: Vec::with_capacity(n),
            group_idx: Vec::with_capacity(n),
            gpc_idx: Vec::with_capacity(n),
            last_issue: vec![0; n],
            issued: vec![0; n],
            completed: vec![0; n],
            counted_accesses: vec![0; n],
            latency_sum: vec![0; n],
            utlb_hits: vec![0; n],
            utlb_lookups: vec![0; n],
        };
        for (i, a) in spec.assignments.iter().enumerate() {
            let g = self.topo.group_of(a.smid);
            sms.stream.push(Stream::new(
                a.pattern.clone(),
                spec.seed
                    .wrapping_mul(0x9E37_79B9_7F4A_7C15)
                    .wrapping_add(((a.smid as u64) << 20) | i as u64),
            ));
            sms.utlb.push(FullyAssocTlb::new(self.cfg.tlb.utlb_entries));
            sms.group_idx.push(group_idx_of[g] as u32);
            sms.gpc_idx.push(self.topo.gpc_of_group(g) as u32);
        }

        RunState {
            shift,
            hit_ps: ns_to_ps(self.cfg.tlb.hit_ns),
            issue_iv: ns_to_ps(self.cfg.sm.issue_interval_ns),
            sms,
            groups,
            hubs,
            hbm,
        }
    }

    /// Run one benchmark measurement (calendar-queue event core).
    pub fn run(&self, spec: &MeasurementSpec) -> Measurement {
        let mut st = self.build_run_state(spec);
        let outstanding = self.cfg.sm.outstanding as u64;
        let n_sms = spec.assignments.len();
        let warmup = ((spec.accesses_per_sm as f64) * spec.warmup_fraction) as u64;
        let issue_iv = st.issue_iv;

        // One queue event per access: `(completion, sm, issue_time)`.
        let mut q = CalendarQueue::new(n_sms * outstanding as usize + 1);
        // Stagger initial slot issues by the issue interval, slot-major so
        // the shared servers see globally nondecreasing arrival times (the
        // virtual-clock FIFO contract; SM-major seeding would present each
        // later SM's t=0 arrivals *after* the previous SM's t=33 ns ones and
        // conjure a phantom standing backlog on near-saturated servers).
        for k in 0..outstanding.min(spec.accesses_per_sm) {
            for i in 0..n_sms as u32 {
                let (done, t_issue) = st.issue(i, k * issue_iv);
                q.push((done, i, t_issue));
            }
        }

        let mut meas_start: Ps = Ps::MAX;
        let mut meas_end: Ps = 0;
        let mut sim_end: Ps = 0;

        while let Some((t, sm, issued)) = q.pop() {
            let i = sm as usize;
            st.sms.completed[i] += 1;
            sim_end = sim_end.max(t);
            if st.sms.completed[i] > warmup {
                st.sms.counted_accesses[i] += 1;
                st.sms.latency_sum[i] += t - issued;
                st.groups[st.sms.group_idx[i] as usize].counted_accesses += 1;
                meas_start = meas_start.min(issued);
                meas_end = meas_end.max(t);
            }
            if st.sms.issued[i] < spec.accesses_per_sm {
                let (done, t_issue) = st.issue(sm, t);
                q.push((done, sm, t_issue));
            }
        }

        aggregate(&st, spec, meas_start, meas_end, sim_end)
    }

    /// Run many independent measurements in parallel on OS threads with the
    /// default worker count.  Results are position-matched to `specs` and
    /// identical to running each spec serially: runs share nothing mutable
    /// except the warm-TLB cache, whose images are deterministic functions
    /// of the region sets.
    pub fn run_many(&self, specs: &[MeasurementSpec]) -> Vec<Measurement> {
        self.run_many_with(specs, crate::util::threads::default_workers())
    }

    /// [`Machine::run_many`] with an explicit worker count.
    pub fn run_many_with(&self, specs: &[MeasurementSpec], workers: usize) -> Vec<Measurement> {
        crate::util::threads::parallel_map(specs, workers, |spec| self.run(spec))
    }

    /// The seed's heap-driven event loop, kept verbatim as the reference
    /// engine: the equivalence tests prove [`Machine::run`] produces
    /// bit-identical `Measurement`s, and `benches/engine_throughput.rs`
    /// reports the calendar engine's speedup against it.  Not a production
    /// path.
    #[doc(hidden)]
    pub fn run_reference_heap(&self, spec: &MeasurementSpec) -> Measurement {
        use std::cmp::Reverse;
        use std::collections::BinaryHeap;

        let mut st = self.build_run_state(spec);
        let outstanding = self.cfg.sm.outstanding as u64;
        let n_sms = spec.assignments.len();
        let warmup = ((spec.accesses_per_sm as f64) * spec.warmup_fraction) as u64;
        let issue_iv = st.issue_iv;

        let mut heap: BinaryHeap<Reverse<(Ps, u32, Ps)>> =
            BinaryHeap::with_capacity(n_sms * outstanding as usize + 1);
        for k in 0..outstanding.min(spec.accesses_per_sm) {
            for i in 0..n_sms as u32 {
                let (done, t_issue) = st.issue(i, k * issue_iv);
                heap.push(Reverse((done, i, t_issue)));
            }
        }

        let mut meas_start: Ps = Ps::MAX;
        let mut meas_end: Ps = 0;
        let mut sim_end: Ps = 0;

        while let Some(Reverse((t, sm, issued))) = heap.pop() {
            let i = sm as usize;
            st.sms.completed[i] += 1;
            sim_end = sim_end.max(t);
            if st.sms.completed[i] > warmup {
                st.sms.counted_accesses[i] += 1;
                st.sms.latency_sum[i] += t - issued;
                st.groups[st.sms.group_idx[i] as usize].counted_accesses += 1;
                meas_start = meas_start.min(issued);
                meas_end = meas_end.max(t);
            }
            if st.sms.issued[i] < spec.accesses_per_sm {
                let (done, t_issue) = st.issue(sm, t);
                heap.push(Reverse((done, sm, t_issue)));
            }
        }

        aggregate(&st, spec, meas_start, meas_end, sim_end)
    }
}

/// Fold a finished run into the reported [`Measurement`].  Counted bytes
/// are exactly `txn * counted_accesses` (every counted access moves one
/// transaction), so no per-SM byte counters are kept.
fn aggregate(
    st: &RunState,
    spec: &MeasurementSpec,
    meas_start: Ps,
    meas_end: Ps,
    sim_end: Ps,
) -> Measurement {
    let txn = spec.txn_bytes;
    let window = meas_end.saturating_sub(meas_start).max(1);
    let counted_accesses: u64 = st.sms.counted_accesses.iter().sum();
    let counted_bytes: u64 = counted_accesses * txn;
    let total_accesses: u64 = st.sms.issued.iter().sum();
    let latency_sum: Ps = st.sms.latency_sum.iter().sum();
    let utlb_hits: u64 = st.sms.utlb_hits.iter().sum();
    let utlb_lookups: u64 = st.sms.utlb_lookups.iter().sum();
    let window_s = window as f64 * 1e-12;
    let gbps = counted_bytes as f64 / 1e9 / window_s;

    let tlb_hits: u64 = st.groups.iter().map(|g| g.tlb.hits()).sum();
    let tlb_misses: u64 = st.groups.iter().map(|g| g.tlb.misses()).sum();
    let per_group = st
        .groups
        .iter()
        .map(|g| GroupStats {
            group: g.group,
            active_sms: g.active_sms,
            accesses: g.counted_accesses,
            tlb_hits: g.tlb.hits(),
            tlb_misses: g.tlb.misses(),
            walks: g.walkers.walks(),
            merged_walks: g.walkers.merged(),
            gbps: (g.counted_accesses * txn) as f64 / 1e9 / window_s,
        })
        .collect();

    Measurement {
        gbps,
        window_ns: ps_to_ns(window),
        sim_ns: ps_to_ns(sim_end),
        counted_accesses,
        total_accesses,
        avg_latency_ns: if counted_accesses > 0 {
            ps_to_ns(latency_sum) / counted_accesses as f64
        } else {
            0.0
        },
        tlb_hit_rate: if tlb_hits + tlb_misses > 0 {
            tlb_hits as f64 / (tlb_hits + tlb_misses) as f64
        } else {
            1.0
        },
        utlb_hit_rate: if utlb_lookups > 0 {
            utlb_hits as f64 / utlb_lookups as f64
        } else {
            0.0
        },
        hbm_utilization: st.hbm.busy_ps() as f64
            / (st.hbm.channel_count() as f64 * sim_end.max(1) as f64),
        per_group,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{MachineConfig, GIB};
    use crate::sim::pages::MemRegion;
    use crate::util::prop;

    fn tiny() -> Machine {
        Machine::new(MachineConfig::tiny_test()).unwrap()
    }

    /// Exhaustive bit-identity check between two measurements.
    fn assert_bit_identical(a: &Measurement, b: &Measurement, what: &str) {
        assert_eq!(a.gbps.to_bits(), b.gbps.to_bits(), "{what}: gbps");
        assert_eq!(a.window_ns.to_bits(), b.window_ns.to_bits(), "{what}: window");
        assert_eq!(a.sim_ns.to_bits(), b.sim_ns.to_bits(), "{what}: sim_ns");
        assert_eq!(a.counted_accesses, b.counted_accesses, "{what}: counted");
        assert_eq!(a.total_accesses, b.total_accesses, "{what}: total");
        assert_eq!(
            a.avg_latency_ns.to_bits(),
            b.avg_latency_ns.to_bits(),
            "{what}: latency"
        );
        assert_eq!(
            a.tlb_hit_rate.to_bits(),
            b.tlb_hit_rate.to_bits(),
            "{what}: tlb_hit_rate"
        );
        assert_eq!(
            a.utlb_hit_rate.to_bits(),
            b.utlb_hit_rate.to_bits(),
            "{what}: utlb_hit_rate"
        );
        assert_eq!(
            a.hbm_utilization.to_bits(),
            b.hbm_utilization.to_bits(),
            "{what}: hbm_utilization"
        );
        assert_eq!(a.per_group.len(), b.per_group.len(), "{what}: group count");
        for (ga, gb) in a.per_group.iter().zip(&b.per_group) {
            assert_eq!(ga.group, gb.group, "{what}: group id");
            assert_eq!(ga.active_sms, gb.active_sms, "{what}: active_sms");
            assert_eq!(ga.accesses, gb.accesses, "{what}: group accesses");
            assert_eq!(ga.tlb_hits, gb.tlb_hits, "{what}: group hits");
            assert_eq!(ga.tlb_misses, gb.tlb_misses, "{what}: group misses");
            assert_eq!(ga.walks, gb.walks, "{what}: walks");
            assert_eq!(ga.merged_walks, gb.merged_walks, "{what}: merged");
            assert_eq!(ga.gbps.to_bits(), gb.gbps.to_bits(), "{what}: group gbps");
        }
    }

    #[test]
    fn calendar_matches_heap_on_resident_region() {
        let m = tiny();
        let spec = MeasurementSpec::uniform_all(
            &m.topology().all_sms(),
            Pattern::Uniform(MemRegion::new(0, 8 << 20)),
            3_000,
            42,
        );
        assert_bit_identical(&m.run(&spec), &m.run_reference_heap(&spec), "resident");
    }

    #[test]
    fn calendar_matches_heap_on_thrash_region() {
        // Past reach: walker backlogs push completions far beyond the
        // calendar ring horizon, exercising the overflow path.
        let m = tiny();
        let spec = MeasurementSpec::uniform_all(
            &m.topology().all_sms(),
            Pattern::Uniform(MemRegion::whole(64 << 20)),
            3_000,
            7,
        );
        assert_bit_identical(&m.run(&spec), &m.run_reference_heap(&spec), "thrash");
    }

    #[test]
    fn property_calendar_engine_is_bit_identical_to_heap() {
        // Seeded random specs over the tiny machine: SM subsets, pattern
        // shapes, transaction sizes, warmup fractions.
        let m = tiny();
        let total = m.config().memory.total_bytes;
        prop::check("calendar-vs-heap", 25, |g| {
            let n_sms = g.usize(1, m.topology().sm_count());
            let mut sms = m.topology().all_sms();
            g.shuffle(&mut sms);
            sms.truncate(n_sms);
            let assignments: Vec<SmAssignment> = sms
                .iter()
                .map(|&smid| {
                    let base = g.u64(0, total / 2) & !0xFFFF;
                    let len = g.u64(1 << 20, total - base);
                    let region = MemRegion::new(base, len);
                    let pattern = match g.usize(0, 3) {
                        0 => Pattern::Uniform(region),
                        1 => Pattern::Sequential(region),
                        2 => Pattern::Strided {
                            region,
                            stride_lines: g.u64(1, 1024),
                        },
                        _ => Pattern::Zipf {
                            region,
                            theta: g.f64(0.5, 0.99),
                        },
                    };
                    SmAssignment { smid, pattern }
                })
                .collect();
            let spec = MeasurementSpec {
                assignments,
                accesses_per_sm: g.u64(100, 2_500),
                warmup_fraction: g.f64(0.0, 0.5),
                txn_bytes: *g.pick(&[128u64, 256, 512]),
                seed: g.u64(0, u64::MAX - 1),
            };
            assert_bit_identical(
                &m.run(&spec),
                &m.run_reference_heap(&spec),
                &format!("case seed {}", g.case_seed),
            );
        });
    }

    #[test]
    fn run_many_matches_serial_runs() {
        let m = tiny();
        let specs: Vec<MeasurementSpec> = (0..8)
            .map(|k| {
                MeasurementSpec::uniform_all(
                    &m.topology().all_sms(),
                    Pattern::Uniform(MemRegion::new(0, (8 + k) << 20)),
                    1_500,
                    100 + k,
                )
            })
            .collect();
        let parallel = m.run_many_with(&specs, 4);
        assert_eq!(parallel.len(), specs.len());
        for (spec, got) in specs.iter().zip(&parallel) {
            assert_bit_identical(got, &m.run(spec), "run_many");
        }
    }

    #[test]
    fn warm_cache_is_shared_across_clones() {
        let m = tiny();
        let spec = MeasurementSpec::uniform_all(
            &m.topology().all_sms(),
            Pattern::Uniform(MemRegion::new(0, 8 << 20)),
            500,
            1,
        );
        let a = m.run(&spec);
        let m2 = m.clone();
        let b = m2.run(&spec);
        assert_bit_identical(&a, &b, "clone");
    }

    #[test]
    fn full_a100_spot_check_calendar_vs_heap() {
        // One spec on the full-size machine: 108 SMs, 14 groups, thrash
        // regime (maximum event-queue pressure).
        let m = Machine::new(MachineConfig::a100_80gb()).unwrap();
        let spec = MeasurementSpec::uniform_all(
            &m.topology().all_sms(),
            Pattern::Uniform(MemRegion::whole(80 * GIB)),
            800,
            3,
        );
        assert_bit_identical(&m.run(&spec), &m.run_reference_heap(&spec), "a100");
    }
}

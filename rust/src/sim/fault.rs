//! Deterministic fault injection for the serving stack.
//!
//! The paper's reach constraint pins every row to one (group, window)
//! pair, so a stalled or dead group is not noise the scheduler can route
//! around implicitly — the serving layer has to recover *deliberately*.
//! Real silicon shows wide per-unit latency variance (stragglers are the
//! common case, not the exception); the DES simulator is uniquely placed
//! to reproduce those failure modes on demand, with a fixed seed, inside
//! tier-1 tests that never touch hardware.
//!
//! A [`FaultPlan`] is a pure-data schedule keyed on each group's **job
//! clock** — the count of sub-batches that group has executed — so the
//! same plan against the same request stream injects the same faults
//! every run.  Three fault modes compose:
//!
//! * **stalls** — a latency multiplier (fixed, or Pareto heavy-tailed)
//!   applied to the simulated per-row cost for a window of jobs; with
//!   `sim_timescale > 0` these become wall-clock stragglers,
//! * **outages** — every job in the window fails (a dead group/card),
//! * **flapping** — the group alternates fail/serve with a period, the
//!   nastiest case for naive health tracking.
//!
//! The [`FaultInjector`] is the runtime half: per-group atomic clocks plus
//! seeded hash draws (no shared RNG state, so concurrent workers stay
//! deterministic per-group).

use std::sync::atomic::{AtomicU64, Ordering};

/// How a stalled job's simulated cost is inflated.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum StallKind {
    /// Multiply the per-row cost by a constant.
    Fixed(f64),
    /// Draw a Pareto-distributed multiplier `x = 1/(1-u)^(1/alpha)`
    /// (heavy tail: most jobs near 1x, rare jobs far out), clamped to
    /// `max`.  Smaller `alpha` = heavier tail.
    Pareto { alpha: f64, max: f64 },
}

/// Stall `group` for jobs `from_job..until_job` on its job clock.
#[derive(Debug, Clone, PartialEq)]
pub struct StallSpec {
    pub group: usize,
    pub from_job: u64,
    pub until_job: u64,
    pub kind: StallKind,
}

/// Fail every job `group` executes in `from_job..until_job`.
#[derive(Debug, Clone, PartialEq)]
pub struct OutageSpec {
    pub group: usize,
    pub from_job: u64,
    pub until_job: u64,
}

/// Alternate `group` between failing and serving with `period` jobs per
/// half-cycle, over `from_job..until_job` (starts in the failing half).
#[derive(Debug, Clone, PartialEq)]
pub struct FlapSpec {
    pub group: usize,
    pub from_job: u64,
    pub until_job: u64,
    pub period: u64,
}

/// A seeded, reproducible schedule of faults keyed on per-group job
/// clocks.  Pure data: cloneable, comparable, and card-shardable via
/// [`FaultPlan::for_card`].
#[derive(Debug, Clone, Default, PartialEq)]
pub struct FaultPlan {
    /// Seed for the Pareto stall draws (schedules themselves are exact).
    pub seed: u64,
    pub stalls: Vec<StallSpec>,
    pub outages: Vec<OutageSpec>,
    pub flaps: Vec<FlapSpec>,
}

impl FaultPlan {
    pub fn new(seed: u64) -> Self {
        Self {
            seed,
            ..Self::default()
        }
    }

    pub fn stall(mut self, group: usize, from_job: u64, until_job: u64, kind: StallKind) -> Self {
        self.stalls.push(StallSpec {
            group,
            from_job,
            until_job,
            kind,
        });
        self
    }

    pub fn outage(mut self, group: usize, from_job: u64, until_job: u64) -> Self {
        self.outages.push(OutageSpec {
            group,
            from_job,
            until_job,
        });
        self
    }

    pub fn flap(mut self, group: usize, from_job: u64, until_job: u64, period: u64) -> Self {
        self.flaps.push(FlapSpec {
            group,
            from_job,
            until_job,
            period,
        });
        self
    }

    /// The chaos-soak schedule: three distinct fault modes spread over the
    /// first `groups` groups (all land on group 0 when there is only one).
    ///
    /// * group 0: a hard outage followed by a slow-recovery stall window
    ///   (the group comes back, but limps before it is healthy),
    /// * group 1: a permanent Pareto heavy tail (stragglers all run long),
    /// * group 2: flapping health mid-run.
    pub fn chaos(seed: u64, groups: usize) -> Self {
        let g = |i: usize| i % groups.max(1);
        Self::new(seed)
            .outage(g(0), 40, 120)
            .stall(g(0), 120, 240, StallKind::Fixed(6.0))
            .stall(
                g(1),
                0,
                u64::MAX,
                StallKind::Pareto {
                    alpha: 1.5,
                    max: 40.0,
                },
            )
            .flap(g(2), 60, 400, 25)
    }

    /// Derive a per-card variant: identical schedule shape, decorrelated
    /// stall draws.  Fleet wiring hands card `i` `plan.for_card(i)` so the
    /// cards do not stall in lockstep.
    pub fn for_card(&self, card: usize) -> Self {
        let mut plan = self.clone();
        plan.seed = self.seed ^ (card as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15);
        plan
    }

    /// True when the plan injects nothing (useful for cheap gating).
    pub fn is_empty(&self) -> bool {
        self.stalls.is_empty() && self.outages.is_empty() && self.flaps.is_empty()
    }
}

/// The fault verdict for one job.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct JobFault {
    /// Latency multiplier to apply to the job's simulated cost (1.0 =
    /// unaffected).  Overlapping stall windows multiply.
    pub stall_mult: f64,
    /// The job must fail instead of executing.
    pub fail: bool,
}

impl JobFault {
    pub const NONE: JobFault = JobFault {
        stall_mult: 1.0,
        fail: false,
    };
}

/// Runtime half of the plan: per-group job clocks + counters.  One
/// injector per backend; workers call [`FaultInjector::next_job`] once
/// per sub-batch *before* touching the output buffer, so injected
/// failures never leave partial writes behind.
pub struct FaultInjector {
    plan: FaultPlan,
    clocks: Vec<AtomicU64>,
    stalls_injected: AtomicU64,
    failures_injected: AtomicU64,
}

impl FaultInjector {
    pub fn new(plan: FaultPlan, groups: usize) -> Self {
        Self {
            plan,
            clocks: (0..groups).map(|_| AtomicU64::new(0)).collect(),
            stalls_injected: AtomicU64::new(0),
            failures_injected: AtomicU64::new(0),
        }
    }

    /// Advance `group`'s job clock and return the fault verdict for the
    /// job at that tick.  Deterministic per (plan, group, tick) — the
    /// clock is the only mutable state, and it only counts.
    pub fn next_job(&self, group: usize) -> JobFault {
        let t = self.clocks[group].fetch_add(1, Ordering::Relaxed);
        self.fault_at(group, t)
    }

    /// The verdict at an explicit clock value (test oracle; `next_job` is
    /// `fault_at(group, clock++)`).
    pub fn fault_at(&self, group: usize, t: u64) -> JobFault {
        let mut fault = JobFault::NONE;
        for o in &self.plan.outages {
            if o.group == group && t >= o.from_job && t < o.until_job {
                fault.fail = true;
            }
        }
        for f in &self.plan.flaps {
            if f.group == group && t >= f.from_job && t < f.until_job && f.period > 0 {
                // Starts failing: the first `period` jobs of the window fail,
                // the next `period` serve, and so on.
                if ((t - f.from_job) / f.period) % 2 == 0 {
                    fault.fail = true;
                }
            }
        }
        for s in &self.plan.stalls {
            if s.group == group && t >= s.from_job && t < s.until_job {
                let mult = match s.kind {
                    StallKind::Fixed(m) => m,
                    StallKind::Pareto { alpha, max } => {
                        let h = splitmix64(
                            self.plan
                                .seed
                                .wrapping_add((group as u64).wrapping_mul(0xA076_1D64_78BD_642F))
                                .wrapping_add(t.wrapping_mul(0xE703_7ED1_A0B4_28DB)),
                        );
                        let u = (h >> 11) as f64 / (1u64 << 53) as f64; // [0, 1)
                        (1.0 / (1.0 - u).powf(1.0 / alpha.max(1e-9))).min(max)
                    }
                };
                fault.stall_mult *= mult.max(0.0);
            }
        }
        if fault.fail {
            self.failures_injected.fetch_add(1, Ordering::Relaxed);
        } else if fault.stall_mult != 1.0 {
            self.stalls_injected.fetch_add(1, Ordering::Relaxed);
        }
        fault
    }

    /// (stalls injected, failures injected) so far.
    pub fn injected(&self) -> (u64, u64) {
        (
            self.stalls_injected.load(Ordering::Relaxed),
            self.failures_injected.load(Ordering::Relaxed),
        )
    }
}

fn splitmix64(x: u64) -> u64 {
    let mut z = x.wrapping_add(0x9E37_79B9_7F4A_7C15);
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn same_plan_same_faults() {
        let plan = FaultPlan::chaos(42, 3);
        let a = FaultInjector::new(plan.clone(), 3);
        let b = FaultInjector::new(plan, 3);
        for g in 0..3 {
            for _ in 0..500 {
                assert_eq!(a.next_job(g), b.next_job(g));
            }
        }
    }

    #[test]
    fn outage_window_fails_exactly() {
        let inj = FaultInjector::new(FaultPlan::new(1).outage(0, 5, 8), 2);
        for t in 0..12 {
            let f = inj.fault_at(0, t);
            assert_eq!(f.fail, (5..8).contains(&t), "t={t}");
            assert!(!inj.fault_at(1, t).fail);
        }
    }

    #[test]
    fn flap_alternates_with_period() {
        let inj = FaultInjector::new(FaultPlan::new(1).flap(0, 10, 30, 5), 1);
        // 10..15 fail, 15..20 serve, 20..25 fail, 25..30 serve.
        for t in 10..30u64 {
            let expect = ((t - 10) / 5) % 2 == 0;
            assert_eq!(inj.fault_at(0, t).fail, expect, "t={t}");
        }
        assert!(!inj.fault_at(0, 9).fail);
        assert!(!inj.fault_at(0, 30).fail);
    }

    #[test]
    fn pareto_stalls_are_heavy_tailed_and_clamped() {
        let inj = FaultInjector::new(
            FaultPlan::new(7).stall(
                0,
                0,
                u64::MAX,
                StallKind::Pareto {
                    alpha: 1.2,
                    max: 30.0,
                },
            ),
            1,
        );
        let mut over_3x = 0;
        for t in 0..2000 {
            let m = inj.fault_at(0, t).stall_mult;
            assert!((1.0..=30.0).contains(&m), "mult {m} at t={t}");
            if m > 3.0 {
                over_3x += 1;
            }
        }
        // Pareto(1.2): P(X > 3) = 3^-1.2 ~ 0.27.  Loose band: the tail is
        // present but not dominant.
        assert!(
            (200..1000).contains(&over_3x),
            "{over_3x}/2000 draws over 3x"
        );
    }

    #[test]
    fn stalls_compose_and_clock_advances() {
        let inj = FaultInjector::new(
            FaultPlan::new(1)
                .stall(0, 0, 10, StallKind::Fixed(2.0))
                .stall(0, 5, 10, StallKind::Fixed(3.0)),
            1,
        );
        assert_eq!(inj.next_job(0).stall_mult, 2.0); // t=0
        for _ in 1..5 {
            inj.next_job(0);
        }
        assert_eq!(inj.next_job(0).stall_mult, 6.0); // t=5: overlap multiplies
        let (stalls, fails) = inj.injected();
        assert_eq!(fails, 0);
        assert_eq!(stalls, 6);
    }

    #[test]
    fn for_card_decorrelates_draws_but_keeps_schedule() {
        let base = FaultPlan::chaos(9, 4);
        let other = base.for_card(3);
        assert_eq!(base.outages, other.outages);
        assert_eq!(base.flaps, other.flaps);
        assert_ne!(base.seed, other.seed);
        assert_eq!(base.for_card(0).seed, base.seed);
        let a = FaultInjector::new(base, 4);
        let b = FaultInjector::new(other, 4);
        // Pareto group (group 1 in chaos()) draws differently per card.
        let diff = (0..100).any(|t| a.fault_at(1, t).stall_mult != b.fault_at(1, t).stall_mult);
        assert!(diff, "per-card seeds should decorrelate Pareto draws");
    }
}

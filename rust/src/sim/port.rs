//! Per-group memory ports and per-GPC hubs.
//!
//! Every memory transaction leaving an SM crosses two shared structures on
//! its way to the crossbar:
//!
//! * the **group port** — one per half-GPC resource group; its bandwidth is
//!   provisioned just above a full group's demand, so it shapes heavy
//!   intra-group contention but leaves a solo group SM-limited (Fig 4);
//! * the **GPC hub** — shared by the two groups of one GPC.  It is
//!   generously provisioned; its only observable effect is a small
//!   arbitration latency when *both* halves of a GPC are active.  This is
//!   the model behind the "more faint pattern in the background" of the
//!   paper's Fig 2 that the paper notes but does not explain.

use crate::config::MemoryConfig;
use crate::sim::queue::{ns_to_ps, svc_ps, Ps, SingleServer};

/// Arbitration penalty (ns) added at the hub while both halves of the GPC
/// are active.  Small by construction: it must stay a *faint* Fig-2 signal.
pub const HUB_ARB_NS: f64 = 6.0;

#[derive(Debug, Clone)]
pub struct GroupPort {
    server: SingleServer,
    svc: Ps,
}

impl GroupPort {
    pub fn new(cfg: &MemoryConfig, txn_bytes: u64) -> Self {
        Self {
            server: SingleServer::new(),
            svc: svc_ps(txn_bytes, cfg.group_port_gbps),
        }
    }

    #[inline]
    pub fn pass(&mut self, t: Ps) -> Ps {
        self.server.serve(t, self.svc)
    }

    pub fn busy_ps(&self) -> Ps {
        self.server.busy_ps()
    }

    pub fn svc_ps(&self) -> Ps {
        self.svc
    }
}

#[derive(Debug, Clone)]
pub struct GpcHub {
    server: SingleServer,
    svc: Ps,
    /// Extra arbitration latency, applied when both halves are active.
    arb: Ps,
    both_halves_active: bool,
}

impl GpcHub {
    pub fn new(cfg: &MemoryConfig, txn_bytes: u64, both_halves_active: bool) -> Self {
        Self {
            server: SingleServer::new(),
            svc: svc_ps(txn_bytes, cfg.gpc_hub_gbps),
            arb: ns_to_ps(HUB_ARB_NS),
            both_halves_active,
        }
    }

    #[inline]
    pub fn pass(&mut self, t: Ps) -> Ps {
        let done = self.server.serve(t, self.svc);
        if self.both_halves_active {
            done + self.arb
        } else {
            done
        }
    }

    pub fn busy_ps(&self) -> Ps {
        self.server.busy_ps()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg() -> MemoryConfig {
        MemoryConfig::a100_80gb()
    }

    #[test]
    fn port_service_time() {
        let p = GroupPort::new(&cfg(), 128);
        // 128 B at 130 GB/s ~ 985 ps.
        assert_eq!(p.svc_ps(), (128.0 / 130.0f64 * 1000.0).round() as Ps);
    }

    #[test]
    fn port_serializes_back_to_back() {
        let mut p = GroupPort::new(&cfg(), 128);
        let a = p.pass(0);
        let b = p.pass(0);
        assert_eq!(b, 2 * a);
    }

    #[test]
    fn hub_arbitration_only_when_both_halves_active() {
        let mut solo = GpcHub::new(&cfg(), 128, false);
        let mut shared = GpcHub::new(&cfg(), 128, true);
        let a = solo.pass(0);
        let b = shared.pass(0);
        assert_eq!(b - a, ns_to_ps(HUB_ARB_NS));
    }

    #[test]
    fn hub_penalty_is_faint_relative_to_memory_latency() {
        // The arbitration penalty must stay well under the base HBM latency
        // so the Fig-2 background pattern remains faint (< 5%).
        assert!(HUB_ARB_NS < cfg().base_latency_ns * 0.05);
    }
}

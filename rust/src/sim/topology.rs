//! Die topology: GPCs, TPCs, SMs, yield harvesting, and the card-specific
//! SM-enumeration permutation.
//!
//! The paper (§1.1): the A100 has 8 GPCs x 8 TPCs x 2 SMs physically; one
//! GPC is fused off and two of the remaining GPCs lose one TPC each, giving
//! 7 GPCs / 54 TPCs / 108 SMs.  `%smid` reveals which SM a block runs on but
//! not which GPC the SM belongs to, "and this may vary card to card".
//!
//! The paper's Fig 3 finding: the unit that shares memory-access resources
//! is the **half-GPC** ("resource group") — 14 groups of 6 or 8 SMs.  We
//! model exactly that: each enabled GPC is split into two halves, each half
//! gets its own TLB + page-walker pool + memory port.

use crate::config::TopologyConfig;
use crate::util::rng::Rng;

/// Index types.  `SmId` is the *enumeration* id visible to software (what
/// `%smid` would report); physical coordinates are hidden inside [`Topology`].
pub type SmId = usize;
pub type GroupId = usize;
pub type GpcId = usize;
pub type TpcId = usize;

/// Physical placement of one SM.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct SmInfo {
    /// Software-visible id (0..sm_count), i.e. simulated `%smid`.
    pub smid: SmId,
    /// Physical GPC (0..enabled_gpcs).
    pub gpc: GpcId,
    /// Physical TPC within the device (global index).
    pub tpc: TpcId,
    /// Memory resource group = half-GPC (0..2*enabled_gpcs).
    pub group: GroupId,
}

/// The die after yield harvesting, with the software SM enumeration.
#[derive(Debug, Clone)]
pub struct Topology {
    sms: Vec<SmInfo>, // indexed by smid
    group_sizes: Vec<usize>,
    gpc_of_group: Vec<GpcId>,
}

impl Topology {
    /// Build the die: distribute enabled TPCs over GPCs (deficit GPCs chosen
    /// by seed), split each GPC into two halves (groups), then assign smids:
    /// the two SMs of one TPC always get consecutive smids (the paper infers
    /// this from the 2x2 blocks in Fig 2), but the *TPC* enumeration order is
    /// a card-specific pseudorandom permutation.
    pub fn build(cfg: &TopologyConfig) -> Self {
        let mut rng = Rng::seed_from_u64(cfg.smid_permutation_seed);

        // 1. Which GPCs lose TPCs?  Spread the deficit round-robin over a
        //    seed-shuffled GPC order.
        let full = cfg.enabled_gpcs * cfg.tpcs_per_gpc;
        assert!(cfg.enabled_tpcs <= full);
        let deficit = full - cfg.enabled_tpcs;
        let mut gpc_order: Vec<GpcId> = (0..cfg.enabled_gpcs).collect();
        rng.shuffle(&mut gpc_order);
        let mut tpcs_in_gpc = vec![cfg.tpcs_per_gpc; cfg.enabled_gpcs];
        for i in 0..deficit {
            tpcs_in_gpc[gpc_order[i % cfg.enabled_gpcs]] -= 1;
        }

        // 2. Lay out TPCs physically and split each GPC into two halves.
        //    A GPC with t TPCs gets halves of ceil(t/2) and floor(t/2) TPCs
        //    (A100: 8 -> 4+4 = two 8-SM groups; 7 -> 4+3 = 8-SM + 6-SM).
        struct PhysTpc {
            gpc: GpcId,
            group: GroupId,
        }
        let mut phys: Vec<PhysTpc> = Vec::with_capacity(cfg.enabled_tpcs);
        for (gpc, &t) in tpcs_in_gpc.iter().enumerate() {
            let first_half = t.div_ceil(2);
            for k in 0..t {
                let half = usize::from(k >= first_half);
                phys.push(PhysTpc {
                    gpc,
                    group: gpc * 2 + half,
                });
            }
        }
        debug_assert_eq!(phys.len(), cfg.enabled_tpcs);

        // 3. Card-specific TPC enumeration: shuffle the physical TPC list;
        //    smids are assigned in shuffled order, two per TPC.
        let mut order: Vec<usize> = (0..phys.len()).collect();
        rng.shuffle(&mut order);

        let mut sms = Vec::with_capacity(cfg.enabled_tpcs * cfg.sms_per_tpc);
        for (enum_tpc, &pidx) in order.iter().enumerate() {
            let p = &phys[pidx];
            for s in 0..cfg.sms_per_tpc {
                sms.push(SmInfo {
                    smid: enum_tpc * cfg.sms_per_tpc + s,
                    gpc: p.gpc,
                    tpc: pidx,
                    group: p.group,
                });
            }
        }

        let n_groups = cfg.enabled_gpcs * 2;
        let mut group_sizes = vec![0usize; n_groups];
        for sm in &sms {
            group_sizes[sm.group] += 1;
        }
        let gpc_of_group = (0..n_groups).map(|g| g / 2).collect();

        Self {
            sms,
            group_sizes,
            gpc_of_group,
        }
    }

    /// Number of software-visible SMs.
    pub fn sm_count(&self) -> usize {
        self.sms.len()
    }

    /// Number of memory resource groups (half-GPCs).
    pub fn group_count(&self) -> usize {
        self.group_sizes.len()
    }

    /// Info for one smid.
    pub fn sm(&self, smid: SmId) -> &SmInfo {
        &self.sms[smid]
    }

    /// Resource group of an smid (ground truth — the probe must *discover*
    /// this without calling it).
    pub fn group_of(&self, smid: SmId) -> GroupId {
        self.sms[smid].group
    }

    /// GPC that a group belongs to (two groups per GPC).
    pub fn gpc_of_group(&self, group: GroupId) -> GpcId {
        self.gpc_of_group[group]
    }

    /// SMs (smids) in one group, ascending.
    pub fn sms_in_group(&self, group: GroupId) -> Vec<SmId> {
        self.sms
            .iter()
            .filter(|s| s.group == group)
            .map(|s| s.smid)
            .collect()
    }

    /// All groups as smid lists, indexed by group id — the shape a
    /// [`TopologyMap`](crate::probe::TopologyMap) carries (the probe must
    /// *discover* this; ground-truth consumers read it directly).
    pub fn sm_groups(&self) -> Vec<Vec<SmId>> {
        (0..self.group_count())
            .map(|g| self.sms_in_group(g))
            .collect()
    }

    /// Sizes of all groups, indexed by group id.
    pub fn group_sizes(&self) -> &[usize] {
        &self.group_sizes
    }

    /// Groups sorted by (size desc, id) — convenient for experiments.
    pub fn groups_by_size(&self) -> Vec<GroupId> {
        let mut g: Vec<GroupId> = (0..self.group_count()).collect();
        g.sort_by_key(|&id| (usize::MAX - self.group_sizes[id], id));
        g
    }

    /// All smids.
    pub fn all_sms(&self) -> Vec<SmId> {
        (0..self.sm_count()).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::MachineConfig;

    fn a100() -> Topology {
        Topology::build(&MachineConfig::a100_80gb().topology)
    }

    #[test]
    fn a100_has_108_sms_14_groups() {
        let t = a100();
        assert_eq!(t.sm_count(), 108);
        assert_eq!(t.group_count(), 14);
    }

    #[test]
    fn a100_group_sizes_are_12x8_plus_2x6() {
        let t = a100();
        let mut sizes = t.group_sizes().to_vec();
        sizes.sort_unstable();
        let eights = sizes.iter().filter(|&&s| s == 8).count();
        let sixes = sizes.iter().filter(|&&s| s == 6).count();
        assert_eq!((sixes, eights), (2, 12), "sizes = {sizes:?}");
        assert_eq!(sizes.iter().sum::<usize>(), 108);
    }

    #[test]
    fn tpc_mates_have_consecutive_smids_and_same_group() {
        // The paper's Fig-2 observation: dark boxes are 2x2 because the two
        // SMs of a TPC have consecutive indices.
        let t = a100();
        for i in (0..t.sm_count()).step_by(2) {
            let a = t.sm(i);
            let b = t.sm(i + 1);
            assert_eq!(a.tpc, b.tpc);
            assert_eq!(a.group, b.group);
            assert_eq!(a.gpc, b.gpc);
        }
    }

    #[test]
    fn smid_to_group_is_scrambled() {
        // Consecutive smids beyond TPC mates should NOT all be in the same
        // group; the card-specific permutation must scramble them.
        let t = a100();
        let changes = (0..t.sm_count() - 2)
            .step_by(2)
            .filter(|&i| t.group_of(i) != t.group_of(i + 2))
            .count();
        assert!(changes > 30, "enumeration suspiciously ordered: {changes}");
    }

    #[test]
    fn different_seeds_give_different_enumerations() {
        let mut c1 = MachineConfig::a100_80gb().topology;
        let mut c2 = c1.clone();
        c1.smid_permutation_seed = 1;
        c2.smid_permutation_seed = 2;
        let t1 = Topology::build(&c1);
        let t2 = Topology::build(&c2);
        let same = (0..t1.sm_count())
            .filter(|&i| t1.group_of(i) == t2.group_of(i))
            .count();
        assert!(same < t1.sm_count(), "seeds produced identical layouts");
    }

    #[test]
    fn same_seed_is_deterministic() {
        let c = MachineConfig::a100_80gb().topology;
        let t1 = Topology::build(&c);
        let t2 = Topology::build(&c);
        for i in 0..t1.sm_count() {
            assert_eq!(t1.sm(i), t2.sm(i));
        }
    }

    #[test]
    fn groups_partition_sms() {
        let t = a100();
        let mut seen = vec![false; t.sm_count()];
        for g in 0..t.group_count() {
            for sm in t.sms_in_group(g) {
                assert!(!seen[sm]);
                seen[sm] = true;
                assert_eq!(t.group_of(sm), g);
            }
        }
        assert!(seen.iter().all(|&x| x));
    }

    #[test]
    fn sm_groups_matches_per_group_listing() {
        let t = a100();
        let gs = t.sm_groups();
        assert_eq!(gs.len(), t.group_count());
        for (g, sms) in gs.iter().enumerate() {
            assert_eq!(*sms, t.sms_in_group(g));
        }
    }

    #[test]
    fn gpc_of_group_pairs_halves() {
        let t = a100();
        for g in 0..t.group_count() {
            assert_eq!(t.gpc_of_group(g), g / 2);
        }
    }

    #[test]
    fn tiny_topology_consistent() {
        let t = Topology::build(&MachineConfig::tiny_test().topology);
        assert_eq!(t.sm_count(), 12);
        assert_eq!(t.group_count(), 4);
        assert_eq!(t.group_sizes().iter().sum::<usize>(), 12);
    }
}

//! HBM channel model: line-striped channels, transaction-size efficiency,
//! and the fixed access latency.
//!
//! The paper's §2.1 aside: random 128 B transactions achieve ~1300 GB/s of
//! the ~1900 GB/s theoretical peak; 256 B reach ~1400 and 512 B ~1600.
//! We model this with a per-transaction-size efficiency factor applied to
//! the per-channel service bandwidth.

use crate::config::MemoryConfig;
use crate::sim::queue::{svc_ps, Ps, SingleServer};

/// The HBM subsystem: one FIFO server per channel.
#[derive(Debug, Clone)]
pub struct Hbm {
    channels: Vec<SingleServer>,
    /// Service time of one transaction on one channel, ps.
    svc: Ps,
    /// Fixed access latency (row activation + transit), ps.
    base_latency: Ps,
    /// Mask for power-of-two channel counts (fast path), else 0.
    mask: u64,
}

impl Hbm {
    pub fn new(cfg: &MemoryConfig, txn_bytes: u64) -> Self {
        let eff = cfg.txn_efficiency(txn_bytes);
        let per_channel_gbps = cfg.channel_gbps(eff);
        let n = cfg.channels;
        Self {
            channels: vec![SingleServer::new(); n],
            svc: svc_ps(txn_bytes, per_channel_gbps),
            base_latency: crate::sim::queue::ns_to_ps(cfg.base_latency_ns),
            mask: if n.is_power_of_two() { n as u64 - 1 } else { 0 },
        }
    }

    /// Channel serving a given line index.  Lines are striped round-robin
    /// across channels (hash-free: real HBM interleaves physical addresses;
    /// at 128 B granularity round-robin is what the memory controller does).
    #[inline]
    pub fn channel_of(&self, line: u64) -> usize {
        if self.mask != 0 {
            (line & self.mask) as usize
        } else {
            (line % self.channels.len() as u64) as usize
        }
    }

    /// Admit a transaction for `line` arriving at `t`; returns the time its
    /// data is back at the SM (queueing + service + fixed latency).
    #[inline]
    pub fn access(&mut self, t: Ps, line: u64) -> Ps {
        let ch = self.channel_of(line);
        self.channels[ch].serve(t, self.svc) + self.base_latency
    }

    /// Aggregate bandwidth-seconds consumed (utilization accounting).
    pub fn busy_ps(&self) -> Ps {
        self.channels.iter().map(|c| c.busy_ps()).sum()
    }

    pub fn channel_count(&self) -> usize {
        self.channels.len()
    }

    /// Per-transaction service time, ps (for tests/calibration).
    pub fn svc_ps(&self) -> Ps {
        self.svc
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg() -> MemoryConfig {
        MemoryConfig::a100_80gb()
    }

    #[test]
    fn service_time_matches_effective_bandwidth() {
        let h = Hbm::new(&cfg(), 128);
        // per-channel eff bw = 1935*0.68/32 GB/s; svc = 128B / that.
        let per_ch: f64 = 1935.0 * 0.68 / 32.0;
        let expect = (128.0 / per_ch * 1000.0).round() as Ps;
        assert_eq!(h.svc_ps(), expect);
    }

    #[test]
    fn striping_covers_all_channels_uniformly() {
        let h = Hbm::new(&cfg(), 128);
        let mut counts = vec![0u32; h.channel_count()];
        for line in 0..3200u64 {
            counts[h.channel_of(line)] += 1;
        }
        assert!(counts.iter().all(|&c| c == 100));
    }

    #[test]
    fn single_channel_hot_spot_serializes() {
        let mut h = Hbm::new(&cfg(), 128);
        // Same line over and over: all hits one channel, fully serialized.
        let mut last = 0;
        for _ in 0..100 {
            last = h.access(0, 7);
        }
        let svc = h.svc_ps();
        let base = crate::sim::queue::ns_to_ps(cfg().base_latency_ns);
        assert_eq!(last, 100 * svc + base);
    }

    #[test]
    fn spread_lines_run_in_parallel() {
        let mut h = Hbm::new(&cfg(), 128);
        let n = h.channel_count() as u64;
        let mut worst = 0;
        for line in 0..n {
            worst = worst.max(h.access(0, line));
        }
        let base = crate::sim::queue::ns_to_ps(cfg().base_latency_ns);
        // One txn per channel: no queueing anywhere.
        assert_eq!(worst, h.svc_ps() + base);
    }

    #[test]
    fn larger_transactions_more_efficient_per_byte() {
        let h128 = Hbm::new(&cfg(), 128);
        let h512 = Hbm::new(&cfg(), 512);
        let per_byte_128 = h128.svc_ps() as f64 / 128.0;
        let per_byte_512 = h512.svc_ps() as f64 / 512.0;
        assert!(per_byte_512 < per_byte_128);
    }

    #[test]
    fn non_power_of_two_channels() {
        let mut c = cfg();
        c.channels = 10;
        let h = Hbm::new(&c, 128);
        let mut counts = vec![0u32; 10];
        for line in 0..1000u64 {
            counts[h.channel_of(line)] += 1;
        }
        assert!(counts.iter().all(|&x| x == 100));
    }
}

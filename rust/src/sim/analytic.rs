//! Closed-form throughput model: the queueing-theory skeleton under the
//! discrete-event simulator.
//!
//! The DES *measures*; this module *predicts* from first principles, and
//! the test suite cross-validates the two.  The machine is a small network
//! of bottlenecks; steady-state throughput is the fixed point of:
//!
//! * **SM (latency) limit** — each SM keeps W accesses in flight, so it
//!   produces `W / L` accesses/s at mean latency `L` (Little's law).
//! * **TLB hit rate** — LRU under uniform random over `P` pages with
//!   capacity `C`: `h = min(1, C / P)` (exact for random replacement,
//!   asymptotically exact for LRU at P >> C, and exact at P <= C with
//!   low-bit indexing because contiguous regions fill sets evenly).
//! * **walker limit** — misses are served by k walkers of rate `1/walk`;
//!   the group cannot complete more than `k / (walk * m)` accesses/s when
//!   the miss rate is `m = 1 - h`.  Below saturation the walk queue adds
//!   the M/D/k-ish waiting time that inflates `L`.
//! * **port / hub / HBM limits** — plain bandwidth caps.
//!
//! The fixed point is found by iterating latency -> demand -> queue
//! inflation -> latency.

use crate::config::MachineConfig;
use crate::sim::pages::MemRegion;

/// Prediction for one group under uniform random access.
#[derive(Debug, Clone, Copy)]
pub struct GroupPrediction {
    /// Expected steady-state group-TLB hit rate.
    pub hit_rate: f64,
    /// Per-SM line throughput, accesses/s.
    pub per_sm_rate: f64,
    /// Group throughput, GB/s.
    pub gbps: f64,
    /// Binding constraint.
    pub bottleneck: Bottleneck,
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Bottleneck {
    SmLatency,
    Walkers,
    GroupPort,
    Hbm,
}

/// Device-level prediction.
#[derive(Debug, Clone)]
pub struct Prediction {
    pub per_group: Vec<GroupPrediction>,
    pub gbps: f64,
}

/// The analytic machine model.
pub struct Analytic<'c> {
    cfg: &'c MachineConfig,
}

impl<'c> Analytic<'c> {
    pub fn new(cfg: &'c MachineConfig) -> Self {
        Self { cfg }
    }

    /// Steady-state group-TLB hit rate for uniform random access over a
    /// region (pre-warmed, as the DES does).
    pub fn hit_rate(&self, region: &MemRegion) -> f64 {
        let pages = region.pages(self.cfg.tlb.page_bytes) as f64;
        let cap = self.cfg.tlb.entries as f64;
        (cap / pages).min(1.0)
    }

    /// Unloaded access latency (ns): TLB hit + port + hub + channel service
    /// + HBM base latency.
    pub fn unloaded_latency_ns(&self, txn_bytes: u64) -> f64 {
        let m = &self.cfg.memory;
        let port = txn_bytes as f64 / m.group_port_gbps;
        let hub = txn_bytes as f64 / m.gpc_hub_gbps;
        let chan = txn_bytes as f64 / m.channel_gbps(m.txn_efficiency(txn_bytes));
        self.cfg.tlb.hit_ns + port + hub + chan + m.base_latency_ns
    }

    /// Predict one group of `sms` SMs reading uniformly from `region`.
    ///
    /// Solves the latency/throughput fixed point: the walk-queue wait is
    /// whatever makes walker occupancy self-consistent with the SM's
    /// finite concurrency (Little's law on the walker pool).
    pub fn predict_group(&self, sms: usize, region: &MemRegion, txn_bytes: u64) -> GroupPrediction {
        let cfg = self.cfg;
        let h = self.hit_rate(region);
        let m = 1.0 - h;
        let w = cfg.sm.outstanding as f64;
        let base_l = self.unloaded_latency_ns(txn_bytes);
        let walk = cfg.tlb.walk_ns;
        let k = cfg.tlb.walkers_per_group as f64;

        // Fixed point on the walk-queue wait q (ns).  Demand of misses:
        // lambda_m = sms * W / L(q) * m, with L(q) = base_l + m*(walk+q).
        // Walker occupancy n = lambda_m * (walk + q) (Little), bounded by
        // the SMs' in-flight budget; waiting arises when n > k.
        let mut q = 0.0f64;
        for _ in 0..64 {
            let l = base_l + m * (walk + q);
            let lambda_m = sms as f64 * w / l * m; // misses per ns
            let n = lambda_m * (walk + q); // walks in system
            let q_new = if n > k {
                // Backlogged: each miss waits behind (n - k) peers spread
                // over k servers.
                (n - k) / k * walk
            } else {
                0.0
            };
            if (q_new - q).abs() < 1e-6 {
                q = q_new;
                break;
            }
            // Damped update for stability.
            q = 0.5 * q + 0.5 * q_new;
        }
        let l = base_l + m * (walk + q);
        let sm_rate = w / l * 1e9; // accesses/s per SM
        let mut rate = sms as f64 * sm_rate;
        let mut bottleneck = if q > 0.0 {
            Bottleneck::Walkers
        } else {
            Bottleneck::SmLatency
        };

        // Hard walker ceiling (saturated pool).
        if m > 0.0 {
            let walker_cap = k / (walk * 1e-9) / m;
            if rate > walker_cap {
                rate = walker_cap;
                bottleneck = Bottleneck::Walkers;
            }
        }
        // Port ceiling.
        let port_cap = cfg.memory.group_port_gbps * 1e9 / txn_bytes as f64;
        if rate > port_cap {
            rate = port_cap;
            bottleneck = Bottleneck::GroupPort;
        }
        GroupPrediction {
            hit_rate: h,
            per_sm_rate: rate / sms as f64,
            gbps: rate * txn_bytes as f64 / 1e9,
            bottleneck,
        }
    }

    /// Predict the whole device: every group reading uniformly from its
    /// assigned region (`regions[group]`), all groups concurrently.
    pub fn predict_device(
        &self,
        group_sizes: &[usize],
        regions: &[MemRegion],
        txn_bytes: u64,
    ) -> Prediction {
        assert_eq!(group_sizes.len(), regions.len());
        let mut per_group: Vec<GroupPrediction> = group_sizes
            .iter()
            .zip(regions)
            .map(|(&sms, r)| self.predict_group(sms, r, txn_bytes))
            .collect();
        let raw: f64 = per_group.iter().map(|p| p.gbps).sum();
        // HBM aggregate ceiling.
        let eff = self.cfg.memory.txn_efficiency(txn_bytes);
        let hbm_cap = self.cfg.memory.peak_gbps * eff;
        let gbps = if raw > hbm_cap {
            let scale = hbm_cap / raw;
            for p in per_group.iter_mut() {
                p.gbps *= scale;
                p.per_sm_rate *= scale;
                p.bottleneck = Bottleneck::Hbm;
            }
            hbm_cap
        } else {
            raw
        };
        Prediction { per_group, gbps }
    }

    /// Convenience: all groups read the same region (the Fig-1 uniform arm).
    pub fn predict_uniform(&self, region: MemRegion, txn_bytes: u64) -> Prediction {
        let topo = crate::sim::Topology::build(&self.cfg.topology);
        let sizes: Vec<usize> = topo.group_sizes().to_vec();
        let regions = vec![region; sizes.len()];
        self.predict_device(&sizes, &regions, txn_bytes)
    }
}

#[cfg(test)]
mod tests {
    //! Cross-validation: the DES must land within tolerance of the
    //! closed-form predictions in every regime (plateau, cliff edge,
    //! walker-bound floor) — and vice versa, the analytic model is itself
    //! validated by the structural simulation.

    use super::*;
    use crate::config::{MachineConfig, GIB};
    use crate::sim::{Machine, MeasurementSpec, Pattern};

    fn cfg() -> MachineConfig {
        MachineConfig::a100_80gb()
    }

    fn des_uniform(machine: &Machine, sms: &[usize], gib: u64, per_sm: u64) -> f64 {
        machine
            .run(&MeasurementSpec::uniform_all(
                sms,
                Pattern::Uniform(MemRegion::new(0, gib * GIB)),
                per_sm,
                99,
            ))
            .gbps
    }

    #[test]
    fn hit_rate_formula() {
        let c = cfg();
        let a = Analytic::new(&c);
        assert_eq!(a.hit_rate(&MemRegion::new(0, 32 * GIB)), 1.0);
        assert_eq!(a.hit_rate(&MemRegion::new(0, 64 * GIB)), 1.0);
        let h80 = a.hit_rate(&MemRegion::new(0, 80 * GIB));
        assert!((h80 - 0.8).abs() < 1e-12);
    }

    #[test]
    fn solo_sm_matches_des_within_10pct() {
        let c = cfg();
        let a = Analytic::new(&c);
        let machine = Machine::new(c.clone()).unwrap();
        let p = a.predict_group(1, &MemRegion::new(0, 4 * GIB), 128);
        assert_eq!(p.bottleneck, Bottleneck::SmLatency);
        let des = des_uniform(&machine, &[0], 4, 20_000);
        let rel = (p.gbps - des).abs() / des;
        assert!(rel < 0.10, "analytic {:.1} vs DES {des:.1}", p.gbps);
    }

    #[test]
    fn solo_group_matches_des_within_10pct() {
        let c = cfg();
        let a = Analytic::new(&c);
        let machine = Machine::new(c.clone()).unwrap();
        let big = machine.topology().groups_by_size()[0];
        let sms = machine.topology().sms_in_group(big);
        let p = a.predict_group(sms.len(), &MemRegion::new(0, 40 * GIB), 128);
        let des = des_uniform(&machine, &sms, 40, 8_000);
        let rel = (p.gbps - des).abs() / des;
        assert!(rel < 0.10, "analytic {:.1} vs DES {des:.1}", p.gbps);
    }

    #[test]
    fn device_plateau_matches_des_within_10pct() {
        let c = cfg();
        let a = Analytic::new(&c);
        let machine = Machine::new(c.clone()).unwrap();
        let p = a.predict_uniform(MemRegion::new(0, 32 * GIB), 128);
        assert_eq!(p.per_group[0].bottleneck, Bottleneck::Hbm);
        let des = des_uniform(&machine, &machine.topology().all_sms(), 32, 3_000);
        let rel = (p.gbps - des).abs() / des;
        assert!(rel < 0.10, "analytic {:.1} vs DES {des:.1}", p.gbps);
    }

    #[test]
    fn device_cliff_floor_matches_des_within_25pct() {
        // The walker-bound floor involves the deepest queueing; allow a
        // looser band.
        let c = cfg();
        let a = Analytic::new(&c);
        let machine = Machine::new(c.clone()).unwrap();
        let p = a.predict_uniform(MemRegion::whole(80 * GIB), 128);
        assert!(p
            .per_group
            .iter()
            .all(|g| g.bottleneck == Bottleneck::Walkers));
        let des = des_uniform(&machine, &machine.topology().all_sms(), 80, 3_000);
        let rel = (p.gbps - des).abs() / des;
        assert!(rel < 0.25, "analytic {:.1} vs DES {des:.1}", p.gbps);
    }

    #[test]
    fn cliff_position_tracks_reach_analytically() {
        let c = cfg();
        let a = Analytic::new(&c);
        let at = |gib: u64| a.predict_uniform(MemRegion::new(0, gib * GIB), 128).gbps;
        assert!(at(64) / at(80) > 4.0, "cliff must be steep");
        assert!((at(8) - at(64)).abs() / at(64) < 0.02, "plateau must be flat");
    }

    #[test]
    fn group_to_chunk_predicted_flat() {
        // Analytic version of Fig 6: 14 groups over two 40 GiB halves.
        let c = cfg();
        let a = Analytic::new(&c);
        let machine = Machine::new(c.clone()).unwrap();
        let sizes: Vec<usize> = machine.topology().group_sizes().to_vec();
        let halves = MemRegion::whole(80 * GIB).split(2, c.tlb.page_bytes);
        let regions: Vec<MemRegion> = (0..sizes.len()).map(|g| halves[g % 2]).collect();
        let p = a.predict_device(&sizes, &regions, 128);
        assert!(p.gbps > 1100.0, "predicted {:.0}", p.gbps);
        assert!(p.per_group.iter().all(|g| g.hit_rate == 1.0));
    }

    #[test]
    fn larger_transactions_predicted_faster() {
        let c = cfg();
        let a = Analytic::new(&c);
        let r = MemRegion::new(0, 32 * GIB);
        let t128 = a.predict_uniform(r, 128).gbps;
        let t256 = a.predict_uniform(r, 256).gbps;
        let t512 = a.predict_uniform(r, 512).gbps;
        assert!(t128 < t256 && t256 < t512);
    }
}

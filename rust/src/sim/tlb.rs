//! TLB structures: the per-group set-associative L2 TLB and the per-SM
//! fully-associative micro-TLB.
//!
//! These are *structural* models — real tag arrays with LRU replacement —
//! so hit rates under any access pattern are measured, not assumed.  The
//! per-group TLB's reach (entries x page size = 64 GiB on the A100 preset)
//! is the central quantity of the paper.

/// Sentinel for an empty TLB way.
const EMPTY: u64 = u64::MAX;

/// Set-associative TLB with per-set LRU replacement.
///
/// Flat arrays (`sets x assoc`) of tags and LRU stamps; lookup scans one
/// set (assoc <= 16 in practice, so this is a handful of comparisons).
#[derive(Debug, Clone)]
pub struct SetAssocTlb {
    tags: Vec<u64>,
    stamp: Vec<u64>,
    sets: usize,
    assoc: usize,
    clock: u64,
    hits: u64,
    misses: u64,
}

impl SetAssocTlb {
    pub fn new(entries: usize, assoc: usize) -> Self {
        assert!(assoc >= 1 && entries >= assoc && entries % assoc == 0);
        let sets = entries / assoc;
        Self {
            tags: vec![EMPTY; entries],
            stamp: vec![0; entries],
            sets,
            assoc,
            clock: 0,
            hits: 0,
            misses: 0,
        }
    }

    #[inline]
    fn set_of(&self, page: u64) -> usize {
        // Low-bit indexing, as in real TLBs.  This matters for fidelity: a
        // *contiguous* region of N pages fills sets exactly evenly, so there
        // are no conflict misses below reach (the paper's flat plateau up to
        // 64 GB) and a uniform overflow beyond it (the sharp cliff).  A
        // hashed index would smear pages Poisson-style and erode the
        // plateau well before reach.
        (page % self.sets as u64) as usize
    }

    /// Look up a page; on hit refresh LRU and return true.
    #[inline]
    pub fn lookup(&mut self, page: u64) -> bool {
        self.clock += 1;
        let s = self.set_of(page);
        let base = s * self.assoc;
        for w in 0..self.assoc {
            if self.tags[base + w] == page {
                self.stamp[base + w] = self.clock;
                self.hits += 1;
                return true;
            }
        }
        self.misses += 1;
        false
    }

    /// Install a page (evicting the set's LRU victim if full).
    #[inline]
    pub fn insert(&mut self, page: u64) {
        self.clock += 1;
        let s = self.set_of(page);
        let base = s * self.assoc;
        let mut victim = base;
        let mut oldest = u64::MAX;
        for w in 0..self.assoc {
            let i = base + w;
            if self.tags[i] == page {
                self.stamp[i] = self.clock;
                return; // already present (raced walk)
            }
            if self.tags[i] == EMPTY {
                self.tags[i] = page;
                self.stamp[i] = self.clock;
                return;
            }
            if self.stamp[i] < oldest {
                oldest = self.stamp[i];
                victim = i;
            }
        }
        self.tags[victim] = page;
        self.stamp[victim] = self.clock;
    }

    pub fn hits(&self) -> u64 {
        self.hits
    }

    pub fn misses(&self) -> u64 {
        self.misses
    }

    pub fn reset_stats(&mut self) {
        self.hits = 0;
        self.misses = 0;
    }

    /// Number of valid entries currently resident.
    pub fn occupancy(&self) -> usize {
        self.tags.iter().filter(|&&t| t != EMPTY).count()
    }

    pub fn capacity(&self) -> usize {
        self.tags.len()
    }

    /// Drop all entries (e.g. context switch), keeping stats.
    pub fn flush(&mut self) {
        self.tags.fill(EMPTY);
    }
}

/// Tiny fully-associative LRU TLB (the per-SM uTLB).
#[derive(Debug, Clone)]
pub struct FullyAssocTlb {
    tags: Vec<u64>,
    stamp: Vec<u64>,
    clock: u64,
}

impl FullyAssocTlb {
    pub fn new(entries: usize) -> Self {
        Self {
            tags: vec![EMPTY; entries],
            stamp: vec![0; entries],
            clock: 0,
        }
    }

    /// Lookup-and-fill in one step: the uTLB always caches the translation
    /// it just used (it is refilled from the group TLB, not from memory, so
    /// the fill has no modelled cost of its own).  Returns hit?
    #[inline]
    pub fn access(&mut self, page: u64) -> bool {
        if self.tags.is_empty() {
            return false;
        }
        self.clock += 1;
        let mut victim = 0;
        let mut oldest = u64::MAX;
        for i in 0..self.tags.len() {
            if self.tags[i] == page {
                self.stamp[i] = self.clock;
                return true;
            }
            if self.stamp[i] < oldest {
                oldest = self.stamp[i];
                victim = i;
            }
        }
        self.tags[victim] = page;
        self.stamp[victim] = self.clock;
        false
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn hit_after_insert() {
        let mut t = SetAssocTlb::new(64, 4);
        assert!(!t.lookup(42));
        t.insert(42);
        assert!(t.lookup(42));
        assert_eq!(t.hits(), 1);
        assert_eq!(t.misses(), 1);
    }

    #[test]
    fn capacity_working_set_all_hits_after_warmup() {
        let entries = 256;
        let mut t = SetAssocTlb::new(entries, 8);
        // Working set smaller than half capacity: after one pass, the next
        // passes must hit every time (hash spreads pages over sets; with
        // ws << capacity no set overflows).
        let ws: Vec<u64> = (0..(entries as u64) / 4).collect();
        for &p in &ws {
            if !t.lookup(p) {
                t.insert(p);
            }
        }
        t.reset_stats();
        for _ in 0..3 {
            for &p in &ws {
                assert!(t.lookup(p));
            }
        }
        assert_eq!(t.misses(), 0);
    }

    #[test]
    fn oversized_working_set_misses() {
        let mut t = SetAssocTlb::new(64, 4);
        // Working set 4x capacity, uniform sweep: mostly misses.
        for round in 0..4u64 {
            for p in 0..256u64 {
                if !t.lookup(p) {
                    t.insert(p);
                }
            }
            if round == 0 {
                t.reset_stats();
            }
        }
        let total = t.hits() + t.misses();
        let miss_rate = t.misses() as f64 / total as f64;
        assert!(miss_rate > 0.9, "miss_rate = {miss_rate}");
    }

    #[test]
    fn lru_evicts_oldest_within_set() {
        // Direct-mapped corner: assoc == entries == 1 set of 4.
        let mut t = SetAssocTlb::new(4, 4);
        for p in 0..4 {
            t.insert(p);
        }
        assert!(t.lookup(0)); // refresh 0: LRU is now 1
        t.insert(100); // evicts 1
        assert!(t.lookup(0));
        assert!(!t.lookup(1));
        assert!(t.lookup(2));
        assert!(t.lookup(3));
        assert!(t.lookup(100));
    }

    #[test]
    fn insert_is_idempotent() {
        let mut t = SetAssocTlb::new(16, 4);
        t.insert(5);
        t.insert(5);
        assert_eq!(t.occupancy(), 1);
    }

    #[test]
    fn flush_empties() {
        let mut t = SetAssocTlb::new(16, 4);
        for p in 0..8 {
            t.insert(p);
        }
        assert!(t.occupancy() > 0);
        t.flush();
        assert_eq!(t.occupancy(), 0);
        assert!(!t.lookup(3));
    }

    #[test]
    fn utlb_lru() {
        let mut u = FullyAssocTlb::new(2);
        assert!(!u.access(1)); // fill 1
        assert!(!u.access(2)); // fill 2
        assert!(u.access(1)); // hit, refresh
        assert!(!u.access(3)); // evicts 2
        assert!(!u.access(2));
        assert!(u.access(3));
    }

    #[test]
    fn utlb_zero_entries_never_hits() {
        let mut u = FullyAssocTlb::new(0);
        assert!(!u.access(1));
        assert!(!u.access(1));
    }

    #[test]
    fn reach_statistics_match_uniform_theory() {
        // LRU over uniform random pages: steady-state hit rate ~ C/N for
        // N pages >> C capacity.  This is the mechanism behind the paper's
        // Fig-1 curve; verify the structural model reproduces it.
        let mut rng = crate::util::rng::Rng::seed_from_u64(1);
        let cap = 1024;
        let n_pages = 4096u64; // N = 4C -> expected hit rate ~0.25
        let mut t = SetAssocTlb::new(cap, 8);
        for i in 0..200_000u64 {
            let p = rng.gen_range(n_pages);
            if !t.lookup(p) {
                t.insert(p);
            }
            if i == 50_000 {
                t.reset_stats();
            }
        }
        let hr = t.hits() as f64 / (t.hits() + t.misses()) as f64;
        assert!((hr - 0.25).abs() < 0.03, "hit rate {hr} not ~0.25");
    }
}

//! Virtual memory regions, pages, and lines.
//!
//! Addresses are plain device byte offsets (the simulator models one large
//! device allocation, like the paper's benchmark buffer).  A "line" is one
//! warp-coalesced 128 B access; a "page" is the translation unit.

use crate::config::LINE_BYTES;

/// A contiguous byte range of device memory `[base, base+len)`.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct MemRegion {
    pub base: u64,
    pub len: u64,
}

impl MemRegion {
    pub fn new(base: u64, len: u64) -> Self {
        Self { base, len }
    }

    /// The whole device.
    pub fn whole(total_bytes: u64) -> Self {
        Self::new(0, total_bytes)
    }

    pub fn end(&self) -> u64 {
        self.base + self.len
    }

    pub fn contains(&self, addr: u64) -> bool {
        addr >= self.base && addr < self.end()
    }

    /// Number of whole lines in the region.
    pub fn lines(&self) -> u64 {
        self.len / LINE_BYTES
    }

    /// Number of pages the region touches.
    pub fn pages(&self, page_bytes: u64) -> u64 {
        if self.len == 0 {
            return 0;
        }
        let first = self.base / page_bytes;
        let last = (self.end() - 1) / page_bytes;
        last - first + 1
    }

    /// Split into `n` equal-length page-aligned chunks (last chunk absorbs
    /// the remainder).  Panics if the region has fewer than `n` pages.
    pub fn split(&self, n: usize, page_bytes: u64) -> Vec<MemRegion> {
        assert!(n >= 1);
        assert!(
            self.pages(page_bytes) >= n as u64,
            "cannot split {} bytes into {n} page-aligned chunks",
            self.len
        );
        let raw = self.len / n as u64;
        let chunk = (raw / page_bytes) * page_bytes;
        let mut out = Vec::with_capacity(n);
        let mut base = self.base;
        for i in 0..n {
            let len = if i == n - 1 { self.end() - base } else { chunk };
            out.push(MemRegion::new(base, len));
            base += len;
        }
        out
    }

    /// Intersection, if non-empty.
    pub fn intersect(&self, other: &MemRegion) -> Option<MemRegion> {
        let base = self.base.max(other.base);
        let end = self.end().min(other.end());
        (end > base).then(|| MemRegion::new(base, end - base))
    }
}

/// Page number of a byte address.
#[inline(always)]
pub fn page_of(addr: u64, page_shift: u32) -> u64 {
    addr >> page_shift
}

/// Line index of a byte address.
#[inline(always)]
pub fn line_of(addr: u64) -> u64 {
    addr / LINE_BYTES
}

/// log2 of a power-of-two page size.
pub fn page_shift(page_bytes: u64) -> u32 {
    debug_assert!(page_bytes.is_power_of_two());
    page_bytes.trailing_zeros()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::GIB;

    #[test]
    fn region_basics() {
        let r = MemRegion::new(1024, 4096);
        assert_eq!(r.end(), 5120);
        assert!(r.contains(1024));
        assert!(r.contains(5119));
        assert!(!r.contains(5120));
        assert!(!r.contains(1023));
        assert_eq!(r.lines(), 32);
    }

    #[test]
    fn page_count_spanning() {
        // 2 MiB pages; region from 1 MiB to 5 MiB touches pages 0,1,2.
        let r = MemRegion::new(1 << 20, 4 << 20);
        assert_eq!(r.pages(2 << 20), 3);
        assert_eq!(MemRegion::new(0, 0).pages(2 << 20), 0);
    }

    #[test]
    fn split_halves_are_page_aligned_and_cover() {
        let page = 2u64 << 20;
        let r = MemRegion::whole(80 * GIB);
        let halves = r.split(2, page);
        assert_eq!(halves.len(), 2);
        assert_eq!(halves[0].base, 0);
        assert_eq!(halves[0].len % page, 0);
        assert_eq!(halves[1].end(), r.end());
        assert_eq!(halves[0].len + halves[1].len, r.len);
        assert_eq!(halves[0].end(), halves[1].base);
    }

    #[test]
    fn split_fourteen_chunks() {
        let page = 2u64 << 20;
        let r = MemRegion::whole(80 * GIB);
        let chunks = r.split(14, page);
        assert_eq!(chunks.len(), 14);
        let total: u64 = chunks.iter().map(|c| c.len).sum();
        assert_eq!(total, r.len);
        for w in chunks.windows(2) {
            assert_eq!(w[0].end(), w[1].base);
            assert_eq!(w[0].base % page, 0);
        }
    }

    #[test]
    fn intersect_cases() {
        let a = MemRegion::new(0, 100);
        let b = MemRegion::new(50, 100);
        assert_eq!(a.intersect(&b), Some(MemRegion::new(50, 50)));
        let c = MemRegion::new(100, 10);
        assert_eq!(a.intersect(&c), None);
    }

    #[test]
    fn page_and_line_math() {
        let shift = page_shift(2 << 20);
        assert_eq!(shift, 21);
        assert_eq!(page_of((2 << 20) - 1, shift), 0);
        assert_eq!(page_of(2 << 20, shift), 1);
        assert_eq!(line_of(127), 0);
        assert_eq!(line_of(128), 1);
    }

    #[test]
    #[should_panic(expected = "cannot split")]
    fn split_too_small_panics() {
        MemRegion::new(0, 2 << 20).split(4, 2 << 20);
    }
}

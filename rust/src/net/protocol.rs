//! Wire protocol for the binary TCP channel.
//!
//! Every frame on the wire is `[len: u32 LE][payload: len bytes]` (the
//! prefix is the codec's job — see [`super::codec`]); this module defines
//! the *payload* encoding and keeps two invariants that the robustness
//! story depends on:
//!
//! * **Self-validating frames.**  Element counts are explicit (`n`,
//!   `elems`) and checked against the payload length on decode, and mask
//!   padding bits must be zero — so *any* strict prefix of a valid frame,
//!   and any bit-flip in structural fields, is rejected with an error
//!   rather than misread (property-tested below).
//! * **No panics.**  Decoding untrusted bytes returns `Err`, never
//!   panics; a malicious or truncated frame can only cost its own
//!   connection.
//!
//! Frame kinds: `Hello`/`HelloAck` handshake (magic + version + tenant,
//! answered with the table's row width so clients can size buffers),
//! `Lookup` requests (request id, optional deadline in ms, row ids),
//! `Full`/`Partial` responses (`Partial` carries the validity mask
//! LSB-first, exactly mirroring `Outcome::Partial`), request-scoped
//! `Error` frames, and connection-scoped `Shed` frames (sent before the
//! server closes a connection it refuses to serve — load shedding is
//! explicit, never a silent drop).

use anyhow::{bail, Context};

/// Protocol magic, first field of `Hello` (catches non-protocol clients
/// that happen to produce a plausible length prefix).
pub const MAGIC: u32 = 0xA100_57_AC;
/// Protocol version; `Hello`/`HelloAck` carry it, mismatches are refused.
pub const VERSION: u16 = 1;
/// Default ceiling on a single frame's payload (8 MiB ≈ 64k rows of
/// d=32 f32s); anything larger is rejected before allocation.
pub const DEFAULT_MAX_FRAME: usize = 8 << 20;
/// Ceiling on tenant-name length in `Hello`.
pub const MAX_TENANT_LEN: usize = 256;
/// Ceiling on error-message length on the wire (longer messages are
/// truncated at a char boundary by the encoder).
pub const MAX_MSG_LEN: usize = 256;

const KIND_HELLO: u8 = 0x01;
const KIND_HELLO_ACK: u8 = 0x02;
const KIND_LOOKUP: u8 = 0x03;
const KIND_FULL: u8 = 0x04;
const KIND_PARTIAL: u8 = 0x05;
const KIND_ERROR: u8 = 0x06;
const KIND_SHED: u8 = 0x07;

/// Why a request or connection was refused.  Carried in `Error` (request
/// scope) and `Shed` (connection scope) frames as a u16.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ErrorCode {
    /// Per-tenant or global admission budget exhausted (retryable).
    OverBudget,
    /// Server is draining; no new work accepted (retry elsewhere).
    Draining,
    /// Connection limit reached (retryable after backoff).
    ConnLimit,
    /// The ticket's deadline expired before completion.
    Deadline,
    /// Malformed or out-of-range request (not retryable as-is).
    BadRequest,
    /// Backend failure the edge could not classify.
    Internal,
}

impl ErrorCode {
    /// True for codes that mean "the server refused load it could not
    /// take" — the load-shedding family a client should back off on.
    pub fn is_shed(self) -> bool {
        matches!(
            self,
            ErrorCode::OverBudget | ErrorCode::Draining | ErrorCode::ConnLimit
        )
    }

    fn to_u16(self) -> u16 {
        match self {
            ErrorCode::OverBudget => 1,
            ErrorCode::Draining => 2,
            ErrorCode::ConnLimit => 3,
            ErrorCode::Deadline => 4,
            ErrorCode::BadRequest => 5,
            ErrorCode::Internal => 6,
        }
    }

    fn from_u16(v: u16) -> anyhow::Result<Self> {
        Ok(match v {
            1 => ErrorCode::OverBudget,
            2 => ErrorCode::Draining,
            3 => ErrorCode::ConnLimit,
            4 => ErrorCode::Deadline,
            5 => ErrorCode::BadRequest,
            6 => ErrorCode::Internal,
            other => bail!("unknown error code {other}"),
        })
    }
}

impl std::fmt::Display for ErrorCode {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        let s = match self {
            ErrorCode::OverBudget => "over-budget",
            ErrorCode::Draining => "draining",
            ErrorCode::ConnLimit => "connection-limit",
            ErrorCode::Deadline => "deadline",
            ErrorCode::BadRequest => "bad-request",
            ErrorCode::Internal => "internal",
        };
        f.write_str(s)
    }
}

/// A decoded frame (owned).  The server decodes `Hello`/`Lookup`; the
/// client decodes the rest; tests round-trip all of them.
#[derive(Debug, Clone, PartialEq)]
pub enum Frame {
    Hello {
        version: u16,
        tenant: String,
    },
    HelloAck {
        version: u16,
        /// Row width (f32 elements per row) of the served table.
        d: u32,
        /// Total rows in the served table (valid ids are `0..rows`).
        rows: u64,
    },
    Lookup {
        req_id: u64,
        /// 0 = no deadline.
        deadline_ms: u32,
        rows: Vec<u64>,
    },
    Full {
        req_id: u64,
        /// Row count (client checks `n * d == data.len()`).
        n: u32,
        data: Vec<f32>,
    },
    Partial {
        req_id: u64,
        valid: Vec<bool>,
        data: Vec<f32>,
    },
    Error {
        req_id: u64,
        code: ErrorCode,
        msg: String,
    },
    Shed {
        code: ErrorCode,
        msg: String,
    },
}

// ---------------------------------------------------------------- encode

fn put_u16(buf: &mut Vec<u8>, v: u16) {
    buf.extend_from_slice(&v.to_le_bytes());
}

fn put_u32(buf: &mut Vec<u8>, v: u32) {
    buf.extend_from_slice(&v.to_le_bytes());
}

fn put_u64(buf: &mut Vec<u8>, v: u64) {
    buf.extend_from_slice(&v.to_le_bytes());
}

fn put_str(buf: &mut Vec<u8>, s: &str, cap: usize) {
    // Truncate at a char boundary; messages are advisory, ids are capped
    // by the caller before encode.
    let mut end = s.len().min(cap);
    while end > 0 && !s.is_char_boundary(end) {
        end -= 1;
    }
    let bytes = &s.as_bytes()[..end];
    put_u16(buf, bytes.len() as u16);
    buf.extend_from_slice(bytes);
}

/// Pack a validity mask LSB-first (`valid[0]` is bit 0 of byte 0);
/// padding bits in the final byte are zero (and checked on decode).
pub fn pack_mask(valid: &[bool], out: &mut Vec<u8>) {
    let base = out.len();
    out.resize(base + valid.len().div_ceil(8), 0);
    for (i, &v) in valid.iter().enumerate() {
        if v {
            out[base + i / 8] |= 1 << (i % 8);
        }
    }
}

/// Unpack an LSB-first validity mask of `n` bits, rejecting short masks
/// and nonzero padding bits (a truncated or corrupted mask must never
/// silently widen or shrink the valid set).
pub fn unpack_mask(bytes: &[u8], n: usize) -> anyhow::Result<Vec<bool>> {
    if bytes.len() != n.div_ceil(8) {
        bail!("mask length {} != ceil({n}/8)", bytes.len());
    }
    let mut valid = Vec::with_capacity(n);
    for i in 0..n {
        valid.push(bytes[i / 8] & (1 << (i % 8)) != 0);
    }
    if n % 8 != 0 && bytes[n / 8] >> (n % 8) != 0 {
        bail!("nonzero padding bits in validity mask");
    }
    Ok(valid)
}

/// Encode `Hello` into `buf` (payload only; the codec adds the length
/// prefix).  The buffer is appended to, not cleared.
pub fn encode_hello(buf: &mut Vec<u8>, tenant: &str) {
    buf.push(KIND_HELLO);
    put_u32(buf, MAGIC);
    put_u16(buf, VERSION);
    put_str(buf, tenant, MAX_TENANT_LEN);
}

pub fn encode_hello_ack(buf: &mut Vec<u8>, d: u32, rows: u64) {
    buf.push(KIND_HELLO_ACK);
    put_u16(buf, VERSION);
    put_u32(buf, d);
    put_u64(buf, rows);
}

pub fn encode_lookup(buf: &mut Vec<u8>, req_id: u64, deadline_ms: u32, rows: &[u64]) {
    buf.push(KIND_LOOKUP);
    put_u64(buf, req_id);
    put_u32(buf, deadline_ms);
    put_u32(buf, rows.len() as u32);
    for &r in rows {
        put_u64(buf, r);
    }
}

/// Encode a full response; `n` is the row count (the receiver checks
/// `data.len() == n * d` against its own `d` from the handshake).
pub fn encode_full(buf: &mut Vec<u8>, req_id: u64, n: u32, data: &[f32]) {
    buf.push(KIND_FULL);
    put_u64(buf, req_id);
    put_u32(buf, n);
    put_u32(buf, data.len() as u32);
    for &v in data {
        buf.extend_from_slice(&v.to_le_bytes());
    }
}

pub fn encode_partial(buf: &mut Vec<u8>, req_id: u64, valid: &[bool], data: &[f32]) {
    buf.push(KIND_PARTIAL);
    put_u64(buf, req_id);
    put_u32(buf, valid.len() as u32);
    pack_mask(valid, buf);
    put_u32(buf, data.len() as u32);
    for &v in data {
        buf.extend_from_slice(&v.to_le_bytes());
    }
}

pub fn encode_error(buf: &mut Vec<u8>, req_id: u64, code: ErrorCode, msg: &str) {
    buf.push(KIND_ERROR);
    put_u64(buf, req_id);
    put_u16(buf, code.to_u16());
    put_str(buf, msg, MAX_MSG_LEN);
}

pub fn encode_shed(buf: &mut Vec<u8>, code: ErrorCode, msg: &str) {
    buf.push(KIND_SHED);
    put_u16(buf, code.to_u16());
    put_str(buf, msg, MAX_MSG_LEN);
}

// ---------------------------------------------------------------- decode

/// Bounds-checked reader over an untrusted payload.
struct Cursor<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> Cursor<'a> {
    fn new(buf: &'a [u8]) -> Self {
        Self { buf, pos: 0 }
    }

    fn take(&mut self, n: usize) -> anyhow::Result<&'a [u8]> {
        let end = self
            .pos
            .checked_add(n)
            .filter(|&e| e <= self.buf.len())
            .context("truncated frame")?;
        let s = &self.buf[self.pos..end];
        self.pos = end;
        Ok(s)
    }

    fn u8(&mut self) -> anyhow::Result<u8> {
        Ok(self.take(1)?[0])
    }

    fn u16(&mut self) -> anyhow::Result<u16> {
        let b = self.take(2)?;
        Ok(u16::from_le_bytes([b[0], b[1]]))
    }

    fn u32(&mut self) -> anyhow::Result<u32> {
        let b = self.take(4)?;
        Ok(u32::from_le_bytes([b[0], b[1], b[2], b[3]]))
    }

    fn u64(&mut self) -> anyhow::Result<u64> {
        let b = self.take(8)?;
        Ok(u64::from_le_bytes([
            b[0], b[1], b[2], b[3], b[4], b[5], b[6], b[7],
        ]))
    }

    fn str16(&mut self, cap: usize) -> anyhow::Result<String> {
        let len = self.u16()? as usize;
        if len > cap {
            bail!("string field length {len} exceeds cap {cap}");
        }
        let bytes = self.take(len)?;
        Ok(std::str::from_utf8(bytes)
            .context("string field is not UTF-8")?
            .to_string())
    }

    fn f32s(&mut self, elems: usize) -> anyhow::Result<Vec<f32>> {
        let bytes = self.take(elems.checked_mul(4).context("element count overflow")?)?;
        let mut v = Vec::with_capacity(elems);
        for c in bytes.chunks_exact(4) {
            v.push(f32::from_le_bytes([c[0], c[1], c[2], c[3]]));
        }
        Ok(v)
    }

    fn finish(&self) -> anyhow::Result<()> {
        if self.pos != self.buf.len() {
            bail!(
                "{} trailing bytes after frame body",
                self.buf.len() - self.pos
            );
        }
        Ok(())
    }
}

/// Decode one payload into an owned [`Frame`].  Strict: unknown kinds,
/// truncation, trailing garbage, bad magic, oversized counts, and
/// nonzero mask padding all fail.
pub fn decode(payload: &[u8]) -> anyhow::Result<Frame> {
    let mut c = Cursor::new(payload);
    let frame = match c.u8()? {
        KIND_HELLO => {
            let magic = c.u32()?;
            if magic != MAGIC {
                bail!("bad protocol magic {magic:#010x}");
            }
            Frame::Hello {
                version: c.u16()?,
                tenant: c.str16(MAX_TENANT_LEN)?,
            }
        }
        KIND_HELLO_ACK => Frame::HelloAck {
            version: c.u16()?,
            d: c.u32()?,
            rows: c.u64()?,
        },
        KIND_LOOKUP => {
            let req_id = c.u64()?;
            let deadline_ms = c.u32()?;
            let n = c.u32()? as usize;
            let bytes = c.take(n.checked_mul(8).context("row count overflow")?)?;
            let mut rows = Vec::with_capacity(n);
            for b in bytes.chunks_exact(8) {
                rows.push(u64::from_le_bytes([
                    b[0], b[1], b[2], b[3], b[4], b[5], b[6], b[7],
                ]));
            }
            Frame::Lookup {
                req_id,
                deadline_ms,
                rows,
            }
        }
        KIND_FULL => {
            let req_id = c.u64()?;
            let n = c.u32()?;
            let elems = c.u32()? as usize;
            Frame::Full {
                req_id,
                n,
                data: c.f32s(elems)?,
            }
        }
        KIND_PARTIAL => {
            let req_id = c.u64()?;
            let n = c.u32()? as usize;
            let mask = c.take(n.div_ceil(8))?;
            let valid = unpack_mask(mask, n)?;
            let elems = c.u32()? as usize;
            Frame::Partial {
                req_id,
                valid,
                data: c.f32s(elems)?,
            }
        }
        KIND_ERROR => Frame::Error {
            req_id: c.u64()?,
            code: ErrorCode::from_u16(c.u16()?)?,
            msg: c.str16(MAX_MSG_LEN)?,
        },
        KIND_SHED => Frame::Shed {
            code: ErrorCode::from_u16(c.u16()?)?,
            msg: c.str16(MAX_MSG_LEN)?,
        },
        other => bail!("unknown frame kind {other:#04x}"),
    };
    c.finish()?;
    Ok(frame)
}

/// A response header decoded without allocating payload vectors — the
/// client's steady-state path (`perf-assert` pins its allocations).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RespHead {
    Full { req_id: u64, n: u32 },
    Partial { req_id: u64, n: u32 },
    Error { req_id: u64, code: ErrorCode },
}

/// Decode a response payload into caller-owned buffers.  `data` and
/// `valid` are cleared and refilled (capacity is reused across calls);
/// for `Error` frames the message is appended to `msg`.  Exactly as
/// strict as [`decode`].
pub fn decode_response_into(
    payload: &[u8],
    data: &mut Vec<f32>,
    valid: &mut Vec<bool>,
    msg: &mut String,
) -> anyhow::Result<RespHead> {
    data.clear();
    valid.clear();
    msg.clear();
    let mut c = Cursor::new(payload);
    let head = match c.u8()? {
        KIND_FULL => {
            let req_id = c.u64()?;
            let n = c.u32()?;
            let elems = c.u32()? as usize;
            let bytes = c.take(elems.checked_mul(4).context("element count overflow")?)?;
            data.reserve(elems);
            for ch in bytes.chunks_exact(4) {
                data.push(f32::from_le_bytes([ch[0], ch[1], ch[2], ch[3]]));
            }
            RespHead::Full { req_id, n }
        }
        KIND_PARTIAL => {
            let req_id = c.u64()?;
            let n = c.u32()? as usize;
            let mask = c.take(n.div_ceil(8))?;
            valid.reserve(n);
            for i in 0..n {
                valid.push(mask[i / 8] & (1 << (i % 8)) != 0);
            }
            if n % 8 != 0 && mask[n / 8] >> (n % 8) != 0 {
                bail!("nonzero padding bits in validity mask");
            }
            let elems = c.u32()? as usize;
            let bytes = c.take(elems.checked_mul(4).context("element count overflow")?)?;
            data.reserve(elems);
            for ch in bytes.chunks_exact(4) {
                data.push(f32::from_le_bytes([ch[0], ch[1], ch[2], ch[3]]));
            }
            RespHead::Partial {
                req_id,
                n: n as u32,
            }
        }
        KIND_ERROR => {
            let req_id = c.u64()?;
            let code = ErrorCode::from_u16(c.u16()?)?;
            msg.push_str(&c.str16(MAX_MSG_LEN)?);
            RespHead::Error { req_id, code }
        }
        other => bail!("unexpected frame kind {other:#04x} in response"),
    };
    c.finish()?;
    Ok(head)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn roundtrip(frame: &Frame) -> Vec<u8> {
        let mut buf = Vec::new();
        match frame {
            Frame::Hello { tenant, .. } => encode_hello(&mut buf, tenant),
            Frame::HelloAck { d, rows, .. } => encode_hello_ack(&mut buf, *d, *rows),
            Frame::Lookup {
                req_id,
                deadline_ms,
                rows,
            } => encode_lookup(&mut buf, *req_id, *deadline_ms, rows),
            Frame::Full { req_id, n, data } => encode_full(&mut buf, *req_id, *n, data),
            Frame::Partial {
                req_id,
                valid,
                data,
            } => encode_partial(&mut buf, *req_id, valid, data),
            Frame::Error { req_id, code, msg } => encode_error(&mut buf, *req_id, *code, msg),
            Frame::Shed { code, msg } => encode_shed(&mut buf, *code, msg),
        }
        assert_eq!(&decode(&buf).unwrap(), frame, "identity broken");
        buf
    }

    /// Every strict prefix of a valid frame must be rejected (never
    /// panic, never decode to something else).
    fn reject_prefixes(buf: &[u8]) {
        for cut in 0..buf.len() {
            assert!(
                decode(&buf[..cut]).is_err(),
                "prefix of {} bytes (of {}) decoded",
                cut,
                buf.len()
            );
        }
    }

    #[test]
    fn handshake_roundtrip() {
        reject_prefixes(&roundtrip(&Frame::Hello {
            version: VERSION,
            tenant: "tenant-a".into(),
        }));
        reject_prefixes(&roundtrip(&Frame::HelloAck {
            version: VERSION,
            d: 32,
            rows: 1 << 20,
        }));
    }

    #[test]
    fn partial_mask_roundtrip_random() {
        // Satellite: encode/decode identity over random masks, and every
        // truncated prefix rejected.
        let mut rng = Rng::seed_from_u64(0xA100);
        for iter in 0..200 {
            let n = rng.gen_index(97);
            let d = 1 + rng.gen_index(8);
            let valid: Vec<bool> = (0..n).map(|_| rng.gen_bool(0.6)).collect();
            let data: Vec<f32> = (0..n * d).map(|i| (i as f32) * 0.5 - 7.0).collect();
            let frame = Frame::Partial {
                req_id: rng.next_u64(),
                valid,
                data,
            };
            let buf = roundtrip(&frame);
            if iter % 16 == 0 {
                reject_prefixes(&buf);
            }
        }
    }

    #[test]
    fn mask_padding_bits_must_be_zero() {
        let valid = vec![true, false, true]; // 3 bits -> 5 padding bits
        let mut buf = Vec::new();
        encode_partial(&mut buf, 9, &valid, &[0.0; 3]);
        assert!(decode(&buf).is_ok());
        // Flip a padding bit in the single mask byte (offset: kind 1 +
        // req_id 8 + n 4 = 13).
        let mut bad = buf.clone();
        bad[13] |= 1 << 6;
        assert!(decode(&bad).is_err(), "padding-bit corruption accepted");
    }

    #[test]
    fn lookup_and_responses_roundtrip() {
        let mut rng = Rng::seed_from_u64(7);
        for _ in 0..50 {
            let n = rng.gen_index(64);
            let rows: Vec<u64> = (0..n).map(|_| rng.next_u64()).collect();
            reject_prefixes(&roundtrip(&Frame::Lookup {
                req_id: rng.next_u64(),
                deadline_ms: rng.gen_range(10_000) as u32,
                rows,
            }));
        }
        let data: Vec<f32> = (0..96).map(|i| i as f32).collect();
        reject_prefixes(&roundtrip(&Frame::Full {
            req_id: 3,
            n: 12,
            data,
        }));
        reject_prefixes(&roundtrip(&Frame::Error {
            req_id: 4,
            code: ErrorCode::Deadline,
            msg: "ticket deadline expired after 1ms".into(),
        }));
        reject_prefixes(&roundtrip(&Frame::Shed {
            code: ErrorCode::Draining,
            msg: "server draining".into(),
        }));
    }

    #[test]
    fn unknown_kind_and_code_rejected() {
        assert!(decode(&[0xEE]).is_err());
        assert!(decode(&[]).is_err());
        // Error frame with an unknown code.
        let mut buf = Vec::new();
        encode_error(&mut buf, 1, ErrorCode::Internal, "x");
        buf[9] = 0xFF; // code lives after kind(1) + req_id(8)
        buf[10] = 0xFF;
        assert!(decode(&buf).is_err());
    }

    #[test]
    fn trailing_garbage_rejected() {
        let mut buf = Vec::new();
        encode_hello_ack(&mut buf, 8, 100);
        buf.push(0);
        assert!(decode(&buf).is_err());
    }

    #[test]
    fn bad_magic_rejected() {
        let mut buf = Vec::new();
        encode_hello(&mut buf, "t");
        buf[1] ^= 0x40; // corrupt magic (after kind byte)
        assert!(decode(&buf).is_err());
    }

    #[test]
    fn decode_into_matches_owned_decode() {
        let mut rng = Rng::seed_from_u64(21);
        let (mut data, mut valid, mut msg) = (Vec::new(), Vec::new(), String::new());
        for _ in 0..100 {
            let n = 1 + rng.gen_index(48);
            let d = 1 + rng.gen_index(6);
            let vmask: Vec<bool> = (0..n).map(|_| rng.gen_bool(0.5)).collect();
            let payload: Vec<f32> = (0..n * d).map(|_| rng.gen_f64() as f32).collect();
            let mut buf = Vec::new();
            encode_partial(&mut buf, 5, &vmask, &payload);
            let head = decode_response_into(&buf, &mut data, &mut valid, &mut msg).unwrap();
            assert_eq!(
                head,
                RespHead::Partial {
                    req_id: 5,
                    n: n as u32
                }
            );
            assert_eq!(data, payload);
            assert_eq!(valid, vmask);
            // Truncations rejected by the into-variant as well.
            for cut in [0, buf.len() / 2, buf.len() - 1] {
                assert!(
                    decode_response_into(&buf[..cut], &mut data, &mut valid, &mut msg).is_err()
                );
            }
        }
    }

    #[test]
    fn long_strings_truncate_at_char_boundary() {
        let long = "é".repeat(300); // 2 bytes per char, 600 bytes total
        let mut buf = Vec::new();
        encode_error(&mut buf, 1, ErrorCode::Internal, &long);
        let Frame::Error { msg, .. } = decode(&buf).unwrap() else {
            panic!("wrong frame");
        };
        assert!(msg.len() <= MAX_MSG_LEN);
        assert!(msg.chars().all(|c| c == 'é'));
    }
}

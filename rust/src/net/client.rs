//! Binary-channel client and a multi-connection pool.
//!
//! [`NetClient`] is one connection: handshake on connect, then
//! `Lookup` → response round trips with *reused* frame/result buffers —
//! steady-state lookups through [`NetClient::lookup_into`] (and the
//! pool's [`RemotePool::request_pinned`]) do not allocate, which is what
//! the `hotpath_alloc` perf-assert counts per connection.
//!
//! Poison discipline: any transport error, torn frame, or protocol
//! desync marks the client poisoned — it refuses further use, and
//! [`RemotePool`] discards it at check-in and dials a replacement on the
//! next checkout.  Server-side *refusals* (`Error` frames: over budget,
//! draining, deadline, bad request) do **not** poison: the connection is
//! intact and the error message carries a machine-matchable prefix
//! (`shed(...)`, `deadline`) so drivers can classify them.
//!
//! [`RemotePool`] is the remote analog of handing `Service` to the
//! workload drivers: `workload::openloop` and `workload::chaos` drive it
//! through the same target traits, optionally with a deterministic
//! client-side fault schedule ([`super::faults::NetFaultPlan`]) so the
//! soak exercises the server's torn-frame and half-close seams.

use std::net::{TcpStream, ToSocketAddrs};
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::Mutex;
use std::time::{Duration, Instant};

use anyhow::{bail, Context};

use crate::service::Outcome;

use super::codec::{begin_frame, read_frame, send_frame, FrameEvent, Transport};
use super::faults::{FaultyTransport, NetFaultInjector, NetFaultPlan};
use super::protocol::{self, Frame, RespHead};

/// Client-side tuning.
#[derive(Debug, Clone)]
pub struct ClientConfig {
    /// Tenant name sent in the `Hello` (admission budgets key on it).
    pub tenant: String,
    /// TCP connect budget.
    pub connect_timeout: Duration,
    /// Budget for one full response (first byte and rest alike).
    pub resp_timeout: Duration,
    /// Frame payload ceiling (must be ≥ the server's for big responses).
    pub max_frame: usize,
}

impl Default for ClientConfig {
    fn default() -> Self {
        Self {
            tenant: "bench".into(),
            connect_timeout: Duration::from_secs(2),
            resp_timeout: Duration::from_secs(10),
            max_frame: protocol::DEFAULT_MAX_FRAME,
        }
    }
}

/// One binary-channel connection (handshake already done).
pub struct NetClient {
    transport: Box<dyn Transport>,
    cfg: ClientConfig,
    /// Reusable receive-payload buffer.
    buf: Vec<u8>,
    /// Reusable frame-assembly buffer.
    out: Vec<u8>,
    /// Reusable error-message buffer (refilled by the response decoder).
    msg: String,
    /// Spare result buffers for the pooled no-allocation path.
    spare_data: Vec<f32>,
    spare_valid: Vec<bool>,
    next_req: u64,
    d: usize,
    rows: u64,
    broken: bool,
}

impl NetClient {
    /// Connect and complete the `Hello`/`HelloAck` handshake.
    pub fn connect(addr: &str, cfg: ClientConfig) -> anyhow::Result<Self> {
        Self::connect_with(addr, cfg, None)
    }

    /// [`NetClient::connect`] with a client-side fault injector wrapped
    /// around the stream (the handshake itself runs through it too).
    pub fn connect_with(
        addr: &str,
        cfg: ClientConfig,
        faults: Option<NetFaultInjector>,
    ) -> anyhow::Result<Self> {
        let sock = addr
            .to_socket_addrs()
            .with_context(|| format!("resolving {addr}"))?
            .next()
            .with_context(|| format!("no address for {addr}"))?;
        let stream = TcpStream::connect_timeout(&sock, cfg.connect_timeout)
            .with_context(|| format!("connecting to {addr}"))?;
        let _ = stream.set_nodelay(true);
        let transport: Box<dyn Transport> = match faults {
            Some(inj) => Box::new(FaultyTransport::new(stream, inj)),
            None => Box::new(stream),
        };
        let mut c = Self {
            transport,
            cfg,
            buf: Vec::with_capacity(4096),
            out: Vec::with_capacity(4096),
            msg: String::new(),
            spare_data: Vec::new(),
            spare_valid: Vec::new(),
            next_req: 0,
            d: 0,
            rows: 0,
            broken: false,
        };
        begin_frame(&mut c.out);
        protocol::encode_hello(&mut c.out, &c.cfg.tenant);
        send_frame(c.transport.as_mut(), &mut c.out, c.cfg.max_frame)
            .context("sending hello")?;
        c.read_reply().context("waiting for hello-ack")?;
        match protocol::decode(&c.buf).context("decoding hello-ack")? {
            Frame::HelloAck { version, d, rows } if version == protocol::VERSION => {
                c.d = d as usize;
                c.rows = rows;
            }
            Frame::HelloAck { version, .. } => bail!(
                "server speaks protocol version {version}, client speaks {}",
                protocol::VERSION
            ),
            Frame::Shed { code, msg } => bail!("shed({code}): {msg}"),
            _ => bail!("unexpected frame in handshake"),
        }
        Ok(c)
    }

    /// Row width of the served table (from the `HelloAck`).
    pub fn d(&self) -> usize {
        self.d
    }

    /// Rows in the served table (valid ids are `0..rows`).
    pub fn rows(&self) -> u64 {
        self.rows
    }

    /// True once this connection must not be reused (transport fault or
    /// protocol desync).  Server-side request refusals do not poison.
    pub fn poisoned(&self) -> bool {
        self.broken || self.transport.poisoned()
    }

    /// One frame into `self.buf`; anything but a frame poisons (the
    /// client is strictly request→response, so Idle/EOF here mean the
    /// server died, stalled past the budget, or a fault fired).
    fn read_reply(&mut self) -> anyhow::Result<()> {
        let event = read_frame(
            self.transport.as_mut(),
            &mut self.buf,
            self.cfg.max_frame,
            self.cfg.resp_timeout,
            self.cfg.resp_timeout,
        );
        match event {
            Ok(FrameEvent::Frame(_)) => Ok(()),
            Ok(FrameEvent::Idle) => {
                self.broken = true;
                bail!("timed out waiting for a response")
            }
            Ok(FrameEvent::Eof) => {
                self.broken = true;
                bail!("connection closed by server")
            }
            Err(e) => {
                self.broken = true;
                Err(e).context("reading response")
            }
        }
    }

    // hotpath: begin (steady-state remote lookup: every buffer is reused)
    /// One lookup round trip, decoded into caller-owned buffers.
    /// Returns `true` if the result is partial (`valid` holds the mask;
    /// masked rows are zero-filled in `out`).
    pub fn lookup_into(
        &mut self,
        rows: &[u64],
        deadline: Option<Duration>,
        out: &mut Vec<f32>,
        valid: &mut Vec<bool>,
    ) -> anyhow::Result<bool> {
        if self.poisoned() {
            bail!("client connection is poisoned");
        }
        self.next_req += 1;
        let req_id = self.next_req;
        let deadline_ms =
            deadline.map_or(0, |d| d.as_millis().min(u128::from(u32::MAX)) as u32);
        begin_frame(&mut self.out);
        protocol::encode_lookup(&mut self.out, req_id, deadline_ms, rows);
        if let Err(e) = send_frame(self.transport.as_mut(), &mut self.out, self.cfg.max_frame) {
            self.broken = true;
            return Err(e).context("sending lookup");
        }
        self.read_reply()?;
        let head = match protocol::decode_response_into(&self.buf, out, valid, &mut self.msg) {
            Ok(h) => h,
            Err(e) => {
                self.broken = true;
                return Err(e).context("decoding response");
            }
        };
        match head {
            RespHead::Full { req_id: rid, .. } if rid == req_id => Ok(false),
            RespHead::Partial { req_id: rid, .. } if rid == req_id => Ok(true),
            // req_id 0 is the server's "before I could parse yours"
            // refusal; the connection is closed right after it.
            RespHead::Error { req_id: rid, code } if rid == req_id || rid == 0 => {
                if code.is_shed() {
                    bail!("shed({code}): {}", self.msg)
                }
                bail!("{code}: {}", self.msg)
            }
            _ => {
                self.broken = true;
                bail!("response for a different request id (protocol desync)")
            }
        }
    }

    /// [`NetClient::lookup_into`] through the client's own spare result
    /// buffers — the pooled, no-allocation-per-request path.
    pub fn lookup_reuse(
        &mut self,
        rows: &[u64],
        deadline: Option<Duration>,
    ) -> anyhow::Result<bool> {
        let mut data = std::mem::take(&mut self.spare_data);
        let mut valid = std::mem::take(&mut self.spare_valid);
        let result = self.lookup_into(rows, deadline, &mut data, &mut valid);
        self.spare_data = data;
        self.spare_valid = valid;
        result
    }
    // hotpath: end

    /// One lookup round trip as an owned [`Outcome`] (allocates; use
    /// [`NetClient::lookup_into`] on measured paths).
    pub fn lookup(
        &mut self,
        rows: &[u64],
        deadline: Option<Duration>,
    ) -> anyhow::Result<Outcome> {
        let mut data = Vec::new();
        let mut valid = Vec::new();
        if self.lookup_into(rows, deadline, &mut data, &mut valid)? {
            Ok(Outcome::Partial { rows: data, valid })
        } else {
            Ok(Outcome::Full(data))
        }
    }
}

/// A bounded pool of [`NetClient`]s sharing one server address: the
/// remote analog of handing `Service` to the workload drivers.
/// Poisoned connections are discarded at check-in and replaced on the
/// next checkout, so injected transport faults cost one request, not
/// the rest of the run.
pub struct RemotePool {
    addr: String,
    cfg: ClientConfig,
    faults: Option<NetFaultPlan>,
    idle: Mutex<Vec<NetClient>>,
    /// Connections dialed so far; doubles as the per-connection fault
    /// schedule index so re-dials get fresh (decorrelated) schedules.
    dialed: AtomicU64,
    /// Live connections (idle + checked out).
    open: AtomicUsize,
    max_conns: usize,
}

impl RemotePool {
    pub fn new(addr: impl Into<String>, cfg: ClientConfig, max_conns: usize) -> Self {
        Self {
            addr: addr.into(),
            cfg,
            faults: None,
            idle: Mutex::new(Vec::new()),
            dialed: AtomicU64::new(0),
            open: AtomicUsize::new(0),
            max_conns: max_conns.max(1),
        }
    }

    /// [`RemotePool::new`] with a deterministic client-side fault plan;
    /// each dialed connection gets its own decorrelated schedule.
    pub fn with_faults(
        addr: impl Into<String>,
        cfg: ClientConfig,
        max_conns: usize,
        faults: NetFaultPlan,
    ) -> Self {
        let mut pool = Self::new(addr, cfg, max_conns);
        if !faults.is_empty() {
            pool.faults = Some(faults);
        }
        pool
    }

    /// Pre-dial up to `n` connections (handshakes included) so the first
    /// measured requests do not pay connection setup.
    pub fn connect_warm(&self, n: usize) -> anyhow::Result<usize> {
        let mut warmed = 0;
        for _ in 0..n.min(self.max_conns) {
            if self.open.fetch_add(1, Ordering::AcqRel) >= self.max_conns {
                self.open.fetch_sub(1, Ordering::AcqRel);
                break;
            }
            match self.dial() {
                Ok(c) => {
                    self.idle.lock().unwrap().push(c);
                    warmed += 1;
                }
                Err(e) => {
                    self.open.fetch_sub(1, Ordering::AcqRel);
                    return Err(e);
                }
            }
        }
        Ok(warmed)
    }

    /// Connections dialed over the pool's lifetime (grows past the pool
    /// size exactly when poisoned connections get replaced).
    pub fn dials(&self) -> u64 {
        self.dialed.load(Ordering::Relaxed)
    }

    /// Row width / table size as reported by the server's `HelloAck`.
    pub fn probe(&self) -> anyhow::Result<(usize, u64)> {
        let c = self.checkout()?;
        let shape = (c.d(), c.rows());
        self.checkin(c);
        Ok(shape)
    }

    /// One request as an owned [`Outcome`] (row-content verification
    /// paths; allocates).
    pub fn request(&self, rows: &[u64], deadline: Option<Duration>) -> anyhow::Result<Outcome> {
        let mut c = self.checkout()?;
        let result = c.lookup(rows, deadline);
        self.checkin(c);
        result
    }

    /// One request through the checked-out client's spare buffers — the
    /// steady-state path allocates nothing per request.
    pub fn request_pinned(&self, rows: &[u64], deadline: Option<Duration>) -> anyhow::Result<()> {
        let mut c = self.checkout()?;
        let result = c.lookup_reuse(rows, deadline).map(|_| ());
        self.checkin(c);
        result
    }

    fn dial(&self) -> anyhow::Result<NetClient> {
        let idx = self.dialed.fetch_add(1, Ordering::Relaxed);
        let inj = self.faults.as_ref().map(|p| p.for_conn(idx));
        NetClient::connect_with(&self.addr, self.cfg.clone(), inj)
    }

    fn checkout(&self) -> anyhow::Result<NetClient> {
        let give_up = Instant::now() + self.cfg.connect_timeout + self.cfg.resp_timeout;
        loop {
            if let Some(c) = self.idle.lock().unwrap().pop() {
                return Ok(c);
            }
            if self.open.fetch_add(1, Ordering::AcqRel) < self.max_conns {
                return match self.dial() {
                    Ok(c) => Ok(c),
                    Err(e) => {
                        self.open.fetch_sub(1, Ordering::AcqRel);
                        Err(e)
                    }
                };
            }
            self.open.fetch_sub(1, Ordering::AcqRel);
            if Instant::now() >= give_up {
                bail!("no pooled connection became available within the wait budget");
            }
            std::thread::sleep(Duration::from_millis(1));
        }
    }

    fn checkin(&self, c: NetClient) {
        if c.poisoned() {
            // Dropped; the next checkout dials a replacement with a
            // fresh fault schedule.
            self.open.fetch_sub(1, Ordering::AcqRel);
            return;
        }
        self.idle.lock().unwrap().push(c);
    }
}

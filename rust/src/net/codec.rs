//! Length-prefixed framing over a pluggable byte transport.
//!
//! `[len: u32 LE][payload: len bytes]`, with the robustness decisions
//! concentrated here so both channel implementations inherit them:
//!
//! * **Bounded before allocated.**  The length prefix is validated
//!   against `max_frame` *before* the payload buffer is grown; a rogue
//!   prefix costs nothing.  Zero-length frames are invalid (there is no
//!   empty payload in the protocol), which also makes plain-text
//!   probes (whose first 4 bytes decode to an absurd length) fail fast.
//! * **Idle vs torn.**  A timeout while waiting for the *first* byte of
//!   a frame is `Idle` — the caller polls again (that is how the server
//!   notices drain-state changes without dedicated wakeups).  A timeout
//!   or EOF *mid-frame* is an error: that is a slow-loris client or a
//!   torn stream, and the connection is closed.
//! * **One writer, one buffer.**  Frames are assembled in a reusable
//!   buffer ([`begin_frame`]/[`send_frame`]) and written with a single
//!   `write_all`, so a frame is never interleaved and the hot path does
//!   not allocate after warmup.
//!
//! The [`Transport`] trait abstracts `TcpStream` so the fault-injecting
//! shim ([`super::faults::FaultyTransport`]) can wrap it; the server
//! side always runs on the plain stream — faults are injected at the
//! client so the *server's* seams are what get exercised.

use std::io::{self, Read, Write};
use std::net::{Shutdown, TcpStream};
use std::time::Duration;

/// Minimal byte-stream surface the codec needs; implemented by
/// `TcpStream` directly and by [`super::faults::FaultyTransport`].
pub trait Transport: Send {
    fn read(&mut self, buf: &mut [u8]) -> io::Result<usize>;
    fn write_all(&mut self, buf: &[u8]) -> io::Result<()>;
    fn set_read_timeout(&mut self, d: Option<Duration>) -> io::Result<()>;
    fn set_write_timeout(&mut self, d: Option<Duration>) -> io::Result<()>;
    /// Half-close the write side (FIN); reads may still proceed.
    fn shutdown_write(&mut self) -> io::Result<()>;
    /// True once the transport is known-dead for further requests (set
    /// by fault injection); pools discard poisoned connections.
    fn poisoned(&self) -> bool {
        false
    }
}

impl Transport for TcpStream {
    fn read(&mut self, buf: &mut [u8]) -> io::Result<usize> {
        Read::read(self, buf)
    }

    fn write_all(&mut self, buf: &[u8]) -> io::Result<()> {
        Write::write_all(self, buf)
    }

    fn set_read_timeout(&mut self, d: Option<Duration>) -> io::Result<()> {
        TcpStream::set_read_timeout(self, d)
    }

    fn set_write_timeout(&mut self, d: Option<Duration>) -> io::Result<()> {
        TcpStream::set_write_timeout(self, d)
    }

    fn shutdown_write(&mut self) -> io::Result<()> {
        self.shutdown(Shutdown::Write)
    }
}

/// Outcome of one [`read_frame`] poll.
#[derive(Debug, PartialEq, Eq)]
pub enum FrameEvent {
    /// A complete payload of this many bytes is in the buffer.
    Frame(usize),
    /// Peer closed cleanly at a frame boundary.
    Eof,
    /// Nothing arrived within the idle window; poll again.
    Idle,
}

fn is_timeout(e: &io::Error) -> bool {
    matches!(
        e.kind(),
        io::ErrorKind::WouldBlock | io::ErrorKind::TimedOut
    )
}

/// `set_read_timeout(Some(0))` is an invalid argument in std; clamp.
fn nonzero(d: Duration) -> Duration {
    if d.is_zero() {
        Duration::from_millis(1)
    } else {
        d
    }
}

fn read_full<T: Transport + ?Sized>(t: &mut T, mut out: &mut [u8]) -> io::Result<()> {
    while !out.is_empty() {
        match t.read(out) {
            Ok(0) => {
                return Err(io::Error::new(
                    io::ErrorKind::UnexpectedEof,
                    "connection closed mid-frame",
                ))
            }
            Ok(n) => out = &mut out[n..],
            Err(e) if e.kind() == io::ErrorKind::Interrupted => {}
            Err(e) if is_timeout(&e) => {
                return Err(io::Error::new(
                    io::ErrorKind::TimedOut,
                    "timed out mid-frame (slow-loris guard)",
                ))
            }
            Err(e) => return Err(e),
        }
    }
    Ok(())
}

/// Read one frame into `buf` (cleared and refilled; capacity reused).
///
/// Waits up to `idle` for the first byte (returning [`FrameEvent::Idle`]
/// if none arrives), then requires the rest of the frame within
/// `frame_timeout` — a client that trickles bytes slower than that loses
/// the connection instead of pinning a server thread.
pub fn read_frame<T: Transport + ?Sized>(
    t: &mut T,
    buf: &mut Vec<u8>,
    max_frame: usize,
    idle: Duration,
    frame_timeout: Duration,
) -> io::Result<FrameEvent> {
    let mut prefix = [0u8; 4];
    t.set_read_timeout(Some(nonzero(idle)))?;
    loop {
        match t.read(&mut prefix[..1]) {
            Ok(0) => return Ok(FrameEvent::Eof),
            Ok(_) => break,
            Err(e) if e.kind() == io::ErrorKind::Interrupted => {}
            Err(e) if is_timeout(&e) => return Ok(FrameEvent::Idle),
            Err(e) => return Err(e),
        }
    }
    // First byte seen: the rest of the frame is on the slow-loris clock.
    t.set_read_timeout(Some(nonzero(frame_timeout)))?;
    read_full(t, &mut prefix[1..])?;
    let len = u32::from_le_bytes(prefix) as usize;
    if len == 0 {
        return Err(io::Error::new(
            io::ErrorKind::InvalidData,
            "zero-length frame",
        ));
    }
    if len > max_frame {
        return Err(io::Error::new(
            io::ErrorKind::InvalidData,
            format!("frame length {len} exceeds cap {max_frame}"),
        ));
    }
    buf.clear();
    buf.resize(len, 0);
    read_full(t, buf)?;
    Ok(FrameEvent::Frame(len))
}

/// Reset `out` to a fresh frame: 4 placeholder bytes for the length
/// prefix, payload appended after by the protocol encoders.
pub fn begin_frame(out: &mut Vec<u8>) {
    out.clear();
    out.extend_from_slice(&[0u8; 4]);
}

/// Patch the length prefix and write the frame with one `write_all`.
/// `out` must have been set up by [`begin_frame`].
pub fn send_frame<T: Transport + ?Sized>(
    t: &mut T,
    out: &mut [u8],
    max_frame: usize,
) -> io::Result<()> {
    let len = match out.len().checked_sub(4) {
        Some(len) if len > 0 && len <= max_frame => len,
        _ => {
            return Err(io::Error::new(
                io::ErrorKind::InvalidData,
                format!("refusing to send frame of {} bytes", out.len()),
            ))
        }
    };
    out[..4].copy_from_slice(&(len as u32).to_le_bytes());
    t.write_all(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::collections::VecDeque;

    /// Scripted transport: reads drain a byte queue; an empty queue is a
    /// timeout, a closed queue is EOF.
    struct Script {
        incoming: VecDeque<u8>,
        closed: bool,
        sent: Vec<u8>,
        /// Serve at most this many bytes per read call (to exercise
        /// partial reads).
        chunk: usize,
    }

    impl Script {
        fn new(bytes: &[u8], closed: bool) -> Self {
            Self {
                incoming: bytes.iter().copied().collect(),
                closed,
                sent: Vec::new(),
                chunk: usize::MAX,
            }
        }
    }

    impl Transport for Script {
        fn read(&mut self, buf: &mut [u8]) -> io::Result<usize> {
            if self.incoming.is_empty() {
                return if self.closed {
                    Ok(0)
                } else {
                    Err(io::Error::new(io::ErrorKind::WouldBlock, "empty"))
                };
            }
            let n = buf.len().min(self.incoming.len()).min(self.chunk).max(1);
            for b in buf.iter_mut().take(n) {
                *b = self.incoming.pop_front().unwrap();
            }
            Ok(n)
        }

        fn write_all(&mut self, buf: &[u8]) -> io::Result<()> {
            self.sent.extend_from_slice(buf);
            Ok(())
        }

        fn set_read_timeout(&mut self, _d: Option<Duration>) -> io::Result<()> {
            Ok(())
        }

        fn set_write_timeout(&mut self, _d: Option<Duration>) -> io::Result<()> {
            Ok(())
        }

        fn shutdown_write(&mut self) -> io::Result<()> {
            Ok(())
        }
    }

    const T: Duration = Duration::from_millis(5);

    fn framed(payload: &[u8]) -> Vec<u8> {
        let mut v = (payload.len() as u32).to_le_bytes().to_vec();
        v.extend_from_slice(payload);
        v
    }

    #[test]
    fn roundtrip_including_partial_reads() {
        let wire = framed(b"hello frame");
        for chunk in [1, 2, usize::MAX] {
            let mut t = Script::new(&wire, true);
            t.chunk = chunk;
            let mut buf = Vec::new();
            assert_eq!(
                read_frame(&mut t, &mut buf, 1 << 20, T, T).unwrap(),
                FrameEvent::Frame(11)
            );
            assert_eq!(&buf, b"hello frame");
            assert_eq!(read_frame(&mut t, &mut buf, 1 << 20, T, T).unwrap(), FrameEvent::Eof);
        }
    }

    #[test]
    fn idle_then_eof_vs_torn() {
        // Empty, open stream: idle (poll again).
        let mut t = Script::new(&[], false);
        let mut buf = Vec::new();
        assert_eq!(read_frame(&mut t, &mut buf, 64, T, T).unwrap(), FrameEvent::Idle);
        // Torn mid-prefix: error, not idle and not eof.
        let mut t = Script::new(&framed(b"abcd")[..2], true);
        let err = read_frame(&mut t, &mut buf, 64, T, T).unwrap_err();
        assert_eq!(err.kind(), io::ErrorKind::UnexpectedEof);
        // Torn mid-payload.
        let mut t = Script::new(&framed(b"abcd")[..6], true);
        let err = read_frame(&mut t, &mut buf, 64, T, T).unwrap_err();
        assert_eq!(err.kind(), io::ErrorKind::UnexpectedEof);
        // Stalled mid-payload (open but silent): slow-loris timeout.
        let mut t = Script::new(&framed(b"abcd")[..6], false);
        let err = read_frame(&mut t, &mut buf, 64, T, T).unwrap_err();
        assert_eq!(err.kind(), io::ErrorKind::TimedOut);
    }

    #[test]
    fn length_bounds_enforced() {
        let mut buf = Vec::new();
        // Zero-length frame.
        let mut t = Script::new(&0u32.to_le_bytes(), true);
        assert_eq!(
            read_frame(&mut t, &mut buf, 64, T, T).unwrap_err().kind(),
            io::ErrorKind::InvalidData
        );
        // Oversized frame rejected before any payload read ("GET " as a
        // length prefix lands here).
        let mut t = Script::new(b"GET / HTTP/1.1\r\n", false);
        assert_eq!(
            read_frame(&mut t, &mut buf, 1 << 20, T, T).unwrap_err().kind(),
            io::ErrorKind::InvalidData
        );
    }

    #[test]
    fn send_frame_patches_prefix() {
        let mut t = Script::new(&[], false);
        let mut out = Vec::new();
        begin_frame(&mut out);
        out.extend_from_slice(b"payload");
        send_frame(&mut t, &mut out, 64).unwrap();
        assert_eq!(t.sent, framed(b"payload"));
        // Empty and oversized payloads refused.
        begin_frame(&mut out);
        assert!(send_frame(&mut t, &mut out, 64).is_err());
        begin_frame(&mut out);
        out.resize(4 + 65, 0);
        assert!(send_frame(&mut t, &mut out, 64).is_err());
    }
}

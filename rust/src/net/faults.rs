//! Seeded transport-fault injection — `sim::FaultPlan`'s idea applied to
//! the wire.
//!
//! A [`NetFaultPlan`] is a deterministic schedule of transport
//! misbehaviors (delay, write splitting, truncation, half-close,
//! connection drop) built with the same builder style as
//! `sim::fault::FaultPlan`.  [`NetFaultPlan::for_conn`] derives a
//! decorrelated per-connection schedule (SplitMix64 over `seed ^ conn`),
//! so a multi-connection soak exercises different fault interleavings on
//! every connection while staying bit-for-bit reproducible.
//!
//! Faults are injected on the **client** side by wrapping its transport
//! in [`FaultyTransport`]; the server keeps its plain `TcpStream`.  That
//! orientation is deliberate: the point of the soak is to prove the
//! *server's* seams survive torn frames, half-closed peers, and
//! mid-stream disconnects without corrupting any other connection's
//! rows (`workload::chaos` does the end-to-end bookkeeping).

use std::io;
use std::time::Duration;

use super::codec::Transport;

/// SplitMix64 (same diffusion step as `sim::fault` and `util::rng`).
fn splitmix64(x: u64) -> u64 {
    let mut z = x.wrapping_add(0x9E37_79B9_7F4A_7C15);
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

/// Deterministic schedule of transport faults.  `*_every = k` fires on
/// a pseudo-random 1-in-`k` subset of operations (0 = never), keyed by
/// the per-connection operation counter — not wall clock — so replays
/// are exact.
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct NetFaultPlan {
    seed: u64,
    delay_every: u64,
    delay_ms: u64,
    split_every: u64,
    truncate_every: u64,
    half_close_every: u64,
    drop_every: u64,
}

impl NetFaultPlan {
    pub fn new(seed: u64) -> Self {
        Self {
            seed,
            ..Self::default()
        }
    }

    /// Sleep `ms` before roughly 1-in-`every` reads and writes.
    pub fn delays(mut self, every: u64, ms: u64) -> Self {
        self.delay_every = every;
        self.delay_ms = ms;
        self
    }

    /// Split roughly 1-in-`every` writes into two syscalls with a pause
    /// between (exercises the server's mid-frame reassembly).
    pub fn splits(mut self, every: u64) -> Self {
        self.split_every = every;
        self
    }

    /// Truncate roughly 1-in-`every` writes (half the bytes, then FIN):
    /// the server must see a torn frame, not a short valid one.
    pub fn truncates(mut self, every: u64) -> Self {
        self.truncate_every = every;
        self
    }

    /// Half-close (FIN after a complete write) roughly 1-in-`every`
    /// writes: the request is intact, the server must still answer it.
    pub fn half_closes(mut self, every: u64) -> Self {
        self.half_close_every = every;
        self
    }

    /// Abandon the connection instead of roughly 1-in-`every` writes.
    pub fn drops(mut self, every: u64) -> Self {
        self.drop_every = every;
        self
    }

    /// The acceptance-soak preset: every fault mode armed at co-prime
    /// rates so schedules interleave rather than align.
    pub fn chaos(seed: u64) -> Self {
        Self::new(seed)
            .delays(7, 2)
            .splits(5)
            .truncates(31)
            .half_closes(41)
            .drops(53)
    }

    pub fn is_empty(&self) -> bool {
        self.delay_every == 0
            && self.split_every == 0
            && self.truncate_every == 0
            && self.half_close_every == 0
            && self.drop_every == 0
    }

    /// Derive this connection's schedule (decorrelated across `conn`).
    pub fn for_conn(&self, conn: u64) -> NetFaultInjector {
        NetFaultInjector {
            plan: self.clone(),
            salt: splitmix64(self.seed ^ conn.wrapping_mul(0xD6E8_FEB8_6659_FD93)),
            writes: 0,
            reads: 0,
            poisoned: false,
        }
    }
}

/// Per-connection fault state: operation counters plus the poison flag
/// that tells the pool this transport is dead for further requests.
#[derive(Debug, Clone)]
pub struct NetFaultInjector {
    plan: NetFaultPlan,
    salt: u64,
    writes: u64,
    reads: u64,
    poisoned: bool,
}

/// What a single write should do (exposed for deterministic tests).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum WriteFault {
    None,
    Delay,
    Split,
    Truncate,
    HalfClose,
    Drop,
}

impl NetFaultInjector {
    fn fires(&self, every: u64, kind: u64, idx: u64) -> bool {
        let h = splitmix64(self.salt ^ kind.wrapping_mul(0xA076_1D64_78BD_642F) ^ idx);
        every != 0 && h % every == 0
    }

    /// Verdict for write number `idx` (highest-severity fault wins).
    pub fn write_fault(&self, idx: u64) -> WriteFault {
        if self.fires(self.plan.truncate_every, 1, idx) {
            WriteFault::Truncate
        } else if self.fires(self.plan.drop_every, 2, idx) {
            WriteFault::Drop
        } else if self.fires(self.plan.half_close_every, 3, idx) {
            WriteFault::HalfClose
        } else if self.fires(self.plan.split_every, 4, idx) {
            WriteFault::Split
        } else if self.fires(self.plan.delay_every, 5, idx) {
            WriteFault::Delay
        } else {
            WriteFault::None
        }
    }

    fn read_delays(&self, idx: u64) -> bool {
        self.fires(self.plan.delay_every, 6, idx)
    }
}

/// A [`Transport`] that misbehaves on the injector's schedule.  Faults
/// that sever the stream (`Truncate`, `Drop`, `HalfClose`) poison the
/// transport so the owning pool retires it instead of reusing it.
pub struct FaultyTransport<T: Transport> {
    inner: T,
    inj: NetFaultInjector,
}

impl<T: Transport> FaultyTransport<T> {
    pub fn new(inner: T, inj: NetFaultInjector) -> Self {
        Self { inner, inj }
    }
}

impl<T: Transport> Transport for FaultyTransport<T> {
    fn read(&mut self, buf: &mut [u8]) -> io::Result<usize> {
        let idx = self.inj.reads;
        self.inj.reads += 1;
        if self.inj.read_delays(idx) {
            std::thread::sleep(Duration::from_millis(self.inj.plan.delay_ms));
        }
        self.inner.read(buf)
    }

    fn write_all(&mut self, buf: &[u8]) -> io::Result<()> {
        let idx = self.inj.writes;
        self.inj.writes += 1;
        if self.inj.poisoned {
            return Err(io::Error::new(
                io::ErrorKind::BrokenPipe,
                "transport poisoned by injected fault",
            ));
        }
        match self.inj.write_fault(idx) {
            WriteFault::None => self.inner.write_all(buf),
            WriteFault::Delay => {
                std::thread::sleep(Duration::from_millis(self.inj.plan.delay_ms));
                self.inner.write_all(buf)
            }
            WriteFault::Split if buf.len() >= 2 => {
                let mid = buf.len() / 2;
                self.inner.write_all(&buf[..mid])?;
                std::thread::sleep(Duration::from_millis((self.inj.plan.delay_ms / 2).max(1)));
                self.inner.write_all(&buf[mid..])
            }
            WriteFault::Split => self.inner.write_all(buf),
            WriteFault::Truncate => {
                self.inj.poisoned = true;
                if buf.len() >= 2 {
                    self.inner.write_all(&buf[..buf.len() / 2])?;
                }
                let _ = self.inner.shutdown_write();
                Err(io::Error::new(
                    io::ErrorKind::BrokenPipe,
                    "injected truncation (torn frame on the wire)",
                ))
            }
            WriteFault::Drop => {
                self.inj.poisoned = true;
                let _ = self.inner.shutdown_write();
                Err(io::Error::new(
                    io::ErrorKind::ConnectionAborted,
                    "injected drop (connection abandoned mid-request)",
                ))
            }
            WriteFault::HalfClose => {
                // The request goes out whole, then FIN: the server must
                // answer a half-closed peer.  Poisoned for *next* use.
                self.inner.write_all(buf)?;
                let _ = self.inner.shutdown_write();
                self.inj.poisoned = true;
                Ok(())
            }
        }
    }

    fn set_read_timeout(&mut self, d: Option<Duration>) -> io::Result<()> {
        self.inner.set_read_timeout(d)
    }

    fn set_write_timeout(&mut self, d: Option<Duration>) -> io::Result<()> {
        self.inner.set_write_timeout(d)
    }

    fn shutdown_write(&mut self) -> io::Result<()> {
        self.inner.shutdown_write()
    }

    fn poisoned(&self) -> bool {
        self.inj.poisoned
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn schedules_are_deterministic_and_decorrelated() {
        let plan = NetFaultPlan::chaos(11);
        let a: Vec<WriteFault> = (0..256).map(|i| plan.for_conn(0).write_fault(i)).collect();
        let b: Vec<WriteFault> = (0..256).map(|i| plan.for_conn(0).write_fault(i)).collect();
        let c: Vec<WriteFault> = (0..256).map(|i| plan.for_conn(1).write_fault(i)).collect();
        assert_eq!(a, b, "same conn, same schedule");
        assert_ne!(a, c, "different conns must decorrelate");
        // Every armed mode fires somewhere in a long enough window.
        for want in [
            WriteFault::Delay,
            WriteFault::Split,
            WriteFault::Truncate,
            WriteFault::HalfClose,
            WriteFault::Drop,
        ] {
            let hit = (0..4096).any(|i| plan.for_conn(3).write_fault(i) == want);
            assert!(hit, "{want:?} never fired");
        }
    }

    #[test]
    fn empty_plan_never_fires() {
        let inj = NetFaultPlan::new(5).for_conn(9);
        assert!(NetFaultPlan::new(5).is_empty());
        assert!((0..1024).all(|i| inj.write_fault(i) == WriteFault::None));
    }

    struct Sink {
        written: Vec<u8>,
        fins: usize,
    }

    impl Transport for Sink {
        fn read(&mut self, _buf: &mut [u8]) -> io::Result<usize> {
            Ok(0)
        }

        fn write_all(&mut self, buf: &[u8]) -> io::Result<()> {
            self.written.extend_from_slice(buf);
            Ok(())
        }

        fn set_read_timeout(&mut self, _d: Option<Duration>) -> io::Result<()> {
            Ok(())
        }

        fn set_write_timeout(&mut self, _d: Option<Duration>) -> io::Result<()> {
            Ok(())
        }

        fn shutdown_write(&mut self) -> io::Result<()> {
            self.fins += 1;
            Ok(())
        }
    }

    #[test]
    fn truncation_writes_a_strict_prefix_then_poisons() {
        // Find a plan/op where write 0 truncates.
        let plan = NetFaultPlan::new(0).truncates(1);
        let mut t = FaultyTransport::new(
            Sink {
                written: Vec::new(),
                fins: 0,
            },
            plan.for_conn(0),
        );
        let err = t.write_all(&[1, 2, 3, 4, 5, 6]).unwrap_err();
        assert_eq!(err.kind(), io::ErrorKind::BrokenPipe);
        assert_eq!(t.inner.written, vec![1, 2, 3]);
        assert_eq!(t.inner.fins, 1);
        assert!(t.poisoned());
        // Poisoned transport refuses further writes.
        assert!(t.write_all(&[9]).is_err());
    }

    #[test]
    fn half_close_delivers_the_write_intact() {
        let plan = NetFaultPlan::new(0).half_closes(1);
        let mut t = FaultyTransport::new(
            Sink {
                written: Vec::new(),
                fins: 0,
            },
            plan.for_conn(0),
        );
        t.write_all(&[7, 8, 9]).unwrap();
        assert_eq!(t.inner.written, vec![7, 8, 9]);
        assert_eq!(t.inner.fins, 1);
        assert!(t.poisoned(), "half-close must poison for the next use");
    }
}

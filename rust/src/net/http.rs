//! Minimal HTTP/1.1 channel: integration lookups and health surface.
//!
//! One short-lived thread per connection, `Connection: close` semantics,
//! hand-rolled request parsing over `util::json` — no framework, no new
//! dependencies.  Three endpoints:
//!
//! * `GET /healthz` — always 200 while the process lives: lifecycle
//!   state, gauges, and edge counters (operators watch a drain here).
//! * `GET /readyz` — 200 only when `Serving` *and* the backend
//!   readiness probe (breaker/health state) agrees; 503 otherwise, so
//!   load balancers stop routing before requests start failing.
//! * `POST /v1/lookup` — `{"tenant", "rows": [...], "deadline_ms"}`;
//!   answers full or partial results as JSON, and maps the same
//!   refusal taxonomy as the binary channel onto status codes
//!   (429 over budget, 503 draining, 504 deadline, 400 bad request).
//!
//! The same hardening applies as on the binary channel: header and body
//! size caps, read timeouts (a slow-loris HTTP client loses the
//! connection), explicit shed responses over the connection limit, and
//! in-flight accounting so a drain waits for HTTP lookups too.

use std::io::{Read, Write};
use std::net::TcpStream;
use std::sync::atomic::Ordering;
use std::sync::Arc;
use std::time::Duration;

use crate::service::Outcome;
use crate::util::json::Json;

use super::protocol::ErrorCode;
use super::server::{ConnGuard, ServerCore};
use super::wire_deadline;

/// Header-block cap (request line + headers).
const MAX_HEAD: usize = 8 << 10;
/// Body cap for `POST /v1/lookup`.
const MAX_BODY: usize = 1 << 20;

/// 503 + close for connections over the HTTP limit (the explicit-shed
/// rule applies to this channel too).
pub(crate) fn shed_and_close(_core: &Arc<ServerCore>, mut stream: TcpStream) {
    let _ = stream.set_write_timeout(Some(Duration::from_millis(200)));
    let body = Json::obj(vec![
        ("error", Json::str("connection limit reached")),
        ("code", Json::str("connection-limit")),
    ])
    .to_string();
    let _ = write_response(&mut stream, 503, "Service Unavailable", &body, true);
}

/// Entry point, one thread per accepted HTTP connection.
pub(crate) fn serve(core: Arc<ServerCore>, mut stream: TcpStream, guard: ConnGuard) {
    let _guard = guard;
    let _ = stream.set_nodelay(true);
    let _ = stream.set_write_timeout(Some(core.cfg.write_timeout));
    let _ = stream.set_read_timeout(Some(core.cfg.hello_timeout + core.cfg.frame_timeout));
    core.metrics.http_requests.fetch_add(1, Ordering::Relaxed);
    let req = match read_request(&mut stream) {
        Ok(r) => r,
        Err(kind) => {
            match kind {
                ReadFail::TooLarge => {
                    let _ = respond_json(
                        &mut stream,
                        413,
                        "Payload Too Large",
                        Json::obj(vec![("error", Json::str("request too large"))]),
                        false,
                    );
                }
                ReadFail::Timeout => {
                    core.metrics.slow_loris_closed.fetch_add(1, Ordering::Relaxed);
                }
                ReadFail::Malformed | ReadFail::Closed => {
                    core.metrics.bad_frames.fetch_add(1, Ordering::Relaxed);
                }
            }
            return;
        }
    };
    match (req.method.as_str(), req.path.as_str()) {
        ("GET", "/healthz") => {
            let _ = respond_json(&mut stream, 200, "OK", healthz(&core), false);
        }
        ("GET", "/readyz") => {
            let ready = core.ready();
            let body = Json::obj(vec![
                ("ready", Json::Bool(ready)),
                ("state", Json::str(core.state_name())),
            ]);
            if ready {
                let _ = respond_json(&mut stream, 200, "OK", body, false);
            } else {
                let _ = respond_json(&mut stream, 503, "Service Unavailable", body, true);
            }
        }
        ("POST", "/v1/lookup") => lookup(&core, &mut stream, &req.body),
        _ => {
            let _ = respond_json(
                &mut stream,
                404,
                "Not Found",
                Json::obj(vec![("error", Json::str("no such endpoint"))]),
                false,
            );
        }
    }
}

fn healthz(core: &Arc<ServerCore>) -> Json {
    let m = core.snapshot();
    let n = |v: u64| Json::num(v as f64);
    Json::obj(vec![
        ("state", Json::str(core.state_name())),
        ("conns_open", Json::num(m.conns_open as f64)),
        ("in_flight", Json::num(m.in_flight as f64)),
        ("conns_accepted", n(m.conns_accepted)),
        ("conns_shed", n(m.conns_shed)),
        ("requests", n(m.requests)),
        ("responses_full", n(m.responses_full)),
        ("responses_partial", n(m.responses_partial)),
        ("responses_error", n(m.responses_error)),
        ("shed_over_budget", n(m.shed_over_budget)),
        ("shed_draining", n(m.shed_draining)),
        ("bad_frames", n(m.bad_frames)),
        ("slow_loris_closed", n(m.slow_loris_closed)),
        ("write_errors", n(m.write_errors)),
        ("http_requests", n(m.http_requests)),
    ])
}

/// `POST /v1/lookup`: parse, validate, admit (same taxonomy as the
/// binary channel), resolve inline, answer JSON.
fn lookup(core: &Arc<ServerCore>, stream: &mut TcpStream, body: &str) {
    let parsed = match Json::parse(body) {
        Ok(p) => p,
        Err(e) => {
            let body = Json::obj(vec![("error", Json::str(format!("bad JSON: {e:?}")))]);
            let _ = respond_json(stream, 400, "Bad Request", body, false);
            return;
        }
    };
    let tenant = parsed
        .get("tenant")
        .and_then(Json::as_str)
        .unwrap_or("http")
        .to_string();
    let items = parsed.get("rows").and_then(Json::as_arr).unwrap_or(&[]);
    let rows: Vec<u64> = items.iter().filter_map(Json::as_u64).collect();
    if rows.is_empty() || rows.len() != items.len() {
        let body = Json::obj(vec![(
            "error",
            Json::str("\"rows\" must be a non-empty array of row ids"),
        )]);
        let _ = respond_json(stream, 400, "Bad Request", body, false);
        return;
    }
    let table_rows = core.target.rows();
    if rows.len() > core.cfg.max_rows_per_request {
        let body = Json::obj(vec![("error", Json::str("too many rows"))]);
        let _ = respond_json(stream, 400, "Bad Request", body, false);
        return;
    }
    if let Some(&bad) = rows.iter().find(|&&r| r >= table_rows) {
        let body = Json::obj(vec![(
            "error",
            Json::str(format!("row {bad} out of range (table has {table_rows} rows)")),
        )]);
        let _ = respond_json(stream, 400, "Bad Request", body, false);
        return;
    }
    let deadline_ms = parsed
        .get("deadline_ms")
        .and_then(Json::as_u64)
        .map_or(0, |v| v.min(u64::from(u32::MAX)) as u32);
    // Count HTTP lookups against the same drain condition as binary
    // requests: a drain waits for this response too.
    core.in_flight.fetch_add(1, Ordering::AcqRel);
    let result = core
        .submit(&tenant, Arc::new(rows), wire_deadline(deadline_ms))
        .map(super::Pending::wait_outcome);
    let d = core.target.d();
    match result {
        Ok(Ok(outcome)) => {
            let (data, valid, partial) = match outcome {
                Outcome::Full(data) => (data, None, false),
                Outcome::Partial { rows, valid } => (rows, Some(valid), true),
            };
            let mut pairs = vec![
                ("d", Json::num(d as f64)),
                ("partial", Json::Bool(partial)),
                (
                    "data",
                    Json::arr(data.iter().map(|&v| Json::num(f64::from(v))).collect()),
                ),
            ];
            if let Some(valid) = &valid {
                pairs.push((
                    "valid",
                    Json::arr(valid.iter().map(|&b| Json::Bool(b)).collect()),
                ));
            }
            let body = Json::obj(pairs);
            core.target.recycle(data);
            let _ = respond_json(stream, 200, "OK", body, false);
        }
        Ok(Err(e)) => {
            let code = super::classify(&e);
            respond_error(stream, code, &format!("{e:#}"));
        }
        Err((code, msg)) => respond_error(stream, code, &msg),
    }
    core.in_flight.fetch_sub(1, Ordering::AcqRel);
}

fn respond_error(stream: &mut TcpStream, code: ErrorCode, msg: &str) {
    let (status, reason, retry) = match code {
        ErrorCode::OverBudget => (429, "Too Many Requests", true),
        ErrorCode::Draining | ErrorCode::ConnLimit => (503, "Service Unavailable", true),
        ErrorCode::Deadline => (504, "Gateway Timeout", false),
        ErrorCode::BadRequest => (400, "Bad Request", false),
        ErrorCode::Internal => (500, "Internal Server Error", false),
    };
    let body = Json::obj(vec![
        ("error", Json::str(msg)),
        ("code", Json::str(code.to_string())),
    ]);
    let _ = respond_json(stream, status, reason, body, retry);
}

struct Request {
    method: String,
    path: String,
    body: String,
}

enum ReadFail {
    TooLarge,
    Timeout,
    Malformed,
    Closed,
}

fn read_request(stream: &mut TcpStream) -> Result<Request, ReadFail> {
    let mut head = Vec::with_capacity(512);
    let mut byte = [0u8; 1];
    // Byte-at-a-time until CRLFCRLF: simple and safe under the size cap
    // (the integration channel is not the hot path).
    while !head.ends_with(b"\r\n\r\n") {
        if head.len() >= MAX_HEAD {
            return Err(ReadFail::TooLarge);
        }
        match stream.read(&mut byte) {
            Ok(0) => return Err(ReadFail::Closed),
            Ok(_) => head.push(byte[0]),
            Err(e) if e.kind() == std::io::ErrorKind::Interrupted => {}
            Err(e)
                if matches!(
                    e.kind(),
                    std::io::ErrorKind::WouldBlock | std::io::ErrorKind::TimedOut
                ) =>
            {
                return Err(ReadFail::Timeout)
            }
            Err(_) => return Err(ReadFail::Closed),
        }
    }
    let head = String::from_utf8_lossy(&head);
    let mut lines = head.split("\r\n");
    let mut first = lines.next().unwrap_or("").split_whitespace();
    let (Some(method), Some(path)) = (first.next(), first.next()) else {
        return Err(ReadFail::Malformed);
    };
    let mut content_length = 0usize;
    for line in lines {
        let Some((k, v)) = line.split_once(':') else {
            continue;
        };
        if k.eq_ignore_ascii_case("content-length") {
            content_length = v.trim().parse().unwrap_or(usize::MAX);
        }
    }
    if content_length > MAX_BODY {
        return Err(ReadFail::TooLarge);
    }
    let mut body = vec![0u8; content_length];
    if content_length > 0 {
        let mut filled = 0usize;
        while filled < content_length {
            match stream.read(&mut body[filled..]) {
                Ok(0) => return Err(ReadFail::Closed),
                Ok(n) => filled += n,
                Err(e) if e.kind() == std::io::ErrorKind::Interrupted => {}
                Err(e)
                    if matches!(
                        e.kind(),
                        std::io::ErrorKind::WouldBlock | std::io::ErrorKind::TimedOut
                    ) =>
                {
                    return Err(ReadFail::Timeout)
                }
                Err(_) => return Err(ReadFail::Closed),
            }
        }
    }
    Ok(Request {
        method: method.to_string(),
        path: path.to_string(),
        body: String::from_utf8_lossy(&body).into_owned(),
    })
}

fn respond_json(
    stream: &mut TcpStream,
    status: u16,
    reason: &str,
    body: Json,
    retry_after: bool,
) -> std::io::Result<()> {
    let body = body.to_string();
    write_response(stream, status, reason, &body, retry_after)
}

fn write_response(
    stream: &mut TcpStream,
    status: u16,
    reason: &str,
    body: &str,
    retry_after: bool,
) -> std::io::Result<()> {
    let retry = if retry_after { "Retry-After: 1\r\n" } else { "" };
    let head = format!(
        "HTTP/1.1 {status} {reason}\r\nContent-Type: application/json\r\n\
         Content-Length: {}\r\nConnection: close\r\n{retry}\r\n",
        body.len()
    );
    stream.write_all(head.as_bytes())?;
    stream.write_all(body.as_bytes())?;
    stream.flush()
}

//! The network front door: a robustness-first socket edge over
//! [`Service`]/[`FleetService`].
//!
//! Two channels on separate listeners, one serving core:
//!
//! ```text
//!   binary TCP (hot path)           HTTP/JSON (integration)
//!   [len][Hello/Lookup/...]         GET /healthz  /readyz   POST /v1/lookup
//!          │                                      │
//!          ▼                                      ▼
//!   conn.rs reader ─ mpsc ─ writer        http.rs (one thread/conn)
//!          │                                      │
//!          └───────────► ServerCore ◄─────────────┘
//!              tenant → Session / GlobalAdmission slot
//!                        │
//!              Target: Service | FleetService
//! ```
//!
//! Robustness decisions, in one place:
//!
//! * **Shedding is explicit.**  Over the connection limit, over a
//!   tenant's admission budget, or while draining, the server *answers*
//!   (a `Shed`/`Error` frame, an HTTP 429/503) and only then closes —
//!   a remote client can always distinguish "refused" from "broken".
//! * **Deadlines travel.**  A `Lookup`'s `deadline_ms` becomes the
//!   ticket deadline, so the backend's culling/partial machinery (PR 6)
//!   works unchanged for remote callers, and `Outcome::Partial` masks
//!   are encoded on the wire rather than flattened into errors.
//! * **Slow clients pay, not the server.**  Reads are polled in short
//!   idle slices (so drain-state changes are noticed) with a separate
//!   mid-frame budget: a client that trickles a frame byte-by-byte
//!   loses its connection (`codec::read_frame`).
//! * **Drain is a lifecycle, not a kill.**  `Serving → Draining`
//!   (accept refused with `Shed`, new requests refused, in-flight
//!   tickets finish) `→ Stopped` (backend shut down, slabs released).
//! * **The whole path is soakable.**  [`faults::NetFaultPlan`] injects
//!   deterministic transport faults client-side, and
//!   [`client::RemotePool`] implements the `workload::openloop` and
//!   `workload::chaos` target traits, so tier-1 drives the real socket
//!   path under fault schedules and verifies every returned row.

pub mod client;
pub mod codec;
pub mod conn;
pub mod faults;
pub mod http;
pub mod protocol;
pub mod server;

use std::sync::Arc;
use std::time::Duration;

use crate::service::session::GlobalSlotGuard;
use crate::service::{FleetService, FleetTicket, Outcome, Service, Ticket};

pub use client::{ClientConfig, NetClient, RemotePool};
pub use faults::{FaultyTransport, NetFaultPlan};
pub use protocol::ErrorCode;
pub use server::{DrainReport, NetConfig, NetMetricsSnapshot, NetServer};

/// What the edge serves: one card or a fleet.  Either way requests are
/// ticketed, deadline-aware, and admission-controlled per tenant.
pub enum Target {
    Single(Service),
    Fleet(Arc<FleetService>),
}

impl Target {
    /// Row width (f32 elements per row).
    pub fn d(&self) -> usize {
        match self {
            Target::Single(s) => s.d(),
            Target::Fleet(f) => f.d(),
        }
    }

    /// Rows in the served table (valid ids are `0..rows`).
    pub fn rows(&self) -> u64 {
        match self {
            Target::Single(s) => s.rows(),
            Target::Fleet(f) => f.rows(),
        }
    }

    /// Return a redeemed result buffer to the backend slab pool.
    pub fn recycle(&self, buf: Vec<f32>) {
        match self {
            Target::Single(s) => s.recycle(buf),
            Target::Fleet(f) => f.recycle(buf),
        }
    }

    /// Drain and stop the backend (idempotent) — the final step of the
    /// server's drain lifecycle, releasing the slab pools.
    pub fn shutdown(&self) {
        match self {
            Target::Single(s) => s.shutdown(),
            Target::Fleet(f) => f.shutdown(),
        }
    }
}

/// An admitted, in-flight request: the ticket plus (fleet path) the
/// tenant's global admission slot, released when the response is
/// written or the request is abandoned.
pub(crate) enum Pending {
    Single(Ticket),
    Fleet(FleetTicket, Option<GlobalSlotGuard>),
}

impl Pending {
    pub(crate) fn wait_outcome(self) -> anyhow::Result<Outcome> {
        match self {
            Pending::Single(t) => t.wait_outcome(),
            Pending::Fleet(t, _slot) => t.wait_outcome(),
        }
    }
}

/// Map a service-layer error onto a wire [`ErrorCode`] by the error
/// chain's text — the service API deliberately exposes `anyhow` chains,
/// and the admission/deadline messages are stable test surface
/// (`tests/resilience.rs` matches on them too).
pub(crate) fn classify(e: &anyhow::Error) -> ErrorCode {
    let s = format!("{e:#}");
    if s.contains("budget") {
        ErrorCode::OverBudget
    } else if s.contains("deadline") {
        ErrorCode::Deadline
    } else {
        ErrorCode::Internal
    }
}

/// Clamp a wire deadline (`deadline_ms`, 0 = none) to a ticket deadline.
pub(crate) fn wire_deadline(deadline_ms: u32) -> Option<Duration> {
    if deadline_ms == 0 {
        None
    } else {
        Some(Duration::from_millis(u64::from(deadline_ms)))
    }
}

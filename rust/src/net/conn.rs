//! Per-connection handling for the binary channel.
//!
//! One reader thread (owns the socket's read side, decodes and admits
//! requests) and one writer thread (owns *all* writes, resolves tickets
//! FIFO) per connection, joined by an mpsc queue — so responses are
//! never interleaved and a slow ticket never blocks the reader from
//! noticing EOF, drain, or the next request.
//!
//! The hardening lives in the reader's refusal paths: every refused
//! request gets an `Error` frame (budget, drain, bad rows) on a
//! *surviving* connection; only protocol violations (undecodable or
//! out-of-order frames) and slow-loris timeouts cost the connection
//! itself.  In-flight accounting ([`WorkGuard`]) is RAII and rides the
//! queue entry, so the drain loop's `in_flight == 0` condition means
//! "every admitted request has had its response written (or its
//! connection died trying)".

use std::net::TcpStream;
use std::sync::atomic::Ordering;
use std::sync::mpsc::{Receiver, Sender};
use std::sync::Arc;
use std::time::Duration;

use crate::service::Outcome;

use super::codec::{begin_frame, read_frame, send_frame, FrameEvent};
use super::protocol::{self, ErrorCode, Frame};
use super::server::{ConnGuard, ServerCore, READ_POLL, STOPPED};
use super::{wire_deadline, Pending};

/// RAII in-flight increment: created before admission, dropped after the
/// response is written (or the request abandoned) — the drain condition
/// counts on this never leaking.
pub(crate) struct WorkGuard(Arc<ServerCore>);

impl WorkGuard {
    fn new(core: &Arc<ServerCore>) -> Self {
        core.in_flight.fetch_add(1, Ordering::AcqRel);
        Self(Arc::clone(core))
    }
}

impl Drop for WorkGuard {
    fn drop(&mut self) {
        self.0.in_flight.fetch_sub(1, Ordering::AcqRel);
    }
}

/// One response owed to the peer, in arrival order.
enum Reply {
    /// Refusal or validation failure: the request never reached the
    /// backend, the connection lives on.
    Immediate {
        req_id: u64,
        code: ErrorCode,
        msg: String,
    },
    /// An admitted ticket; the writer resolves it and encodes the
    /// outcome (`Full`, `Partial`, or `Error`).
    Ticket {
        req_id: u64,
        pending: Pending,
        work: WorkGuard,
    },
}

/// Entry point, run on a dedicated thread per accepted connection.
pub(crate) fn serve(core: Arc<ServerCore>, mut stream: TcpStream, guard: ConnGuard) {
    let _guard = guard;
    let _ = stream.set_nodelay(true);
    let Ok(wstream) = stream.try_clone() else {
        core.metrics.write_errors.fetch_add(1, Ordering::Relaxed);
        return;
    };
    let _ = wstream.set_write_timeout(Some(core.cfg.write_timeout));
    let Some((tenant, wstream)) = handshake(&core, &mut stream, wstream) else {
        return;
    };
    core.metrics.hellos.fetch_add(1, Ordering::Relaxed);

    let (tx, rx) = std::sync::mpsc::channel::<Reply>();
    let wcore = Arc::clone(&core);
    let writer = std::thread::Builder::new()
        .name("net-conn-w".into())
        .spawn(move || write_loop(wcore, wstream, rx));
    let Ok(writer) = writer else {
        core.metrics.write_errors.fetch_add(1, Ordering::Relaxed);
        return;
    };

    read_loop(&core, &mut stream, &tenant, tx);
    // Dropping `tx` (done by read_loop) lets the writer drain queued
    // responses and exit; join so the connection gauge (released by
    // `_guard`) really means "both threads gone".
    let _ = writer.join();
}

/// Expect `Hello` within `hello_timeout`, answer `HelloAck` (row width +
/// table size so the client can size buffers and validate row ids).
/// Returns the tenant and the write stream, or None if the connection
/// was refused or the peer violated the protocol.
fn handshake(
    core: &Arc<ServerCore>,
    stream: &mut TcpStream,
    mut wstream: TcpStream,
) -> Option<(String, TcpStream)> {
    let mut buf = Vec::with_capacity(256);
    let event = read_frame(
        stream,
        &mut buf,
        core.cfg.max_frame,
        core.cfg.hello_timeout,
        core.cfg.frame_timeout,
    );
    let frame = match event {
        Ok(FrameEvent::Frame(_)) => protocol::decode(&buf),
        Ok(FrameEvent::Idle) | Err(_) => {
            core.metrics.slow_loris_closed.fetch_add(1, Ordering::Relaxed);
            return None;
        }
        Ok(FrameEvent::Eof) => return None,
    };
    let tenant = match frame {
        Ok(Frame::Hello { version, tenant }) if version == protocol::VERSION => tenant,
        Ok(Frame::Hello { version, .. }) => {
            refuse(
                core,
                &mut wstream,
                ErrorCode::BadRequest,
                &format!(
                    "unsupported protocol version {version} (server speaks {})",
                    protocol::VERSION
                ),
            );
            return None;
        }
        _ => {
            core.metrics.bad_frames.fetch_add(1, Ordering::Relaxed);
            refuse(
                core,
                &mut wstream,
                ErrorCode::BadRequest,
                "expected Hello as the first frame",
            );
            return None;
        }
    };
    let mut out = Vec::with_capacity(64);
    begin_frame(&mut out);
    protocol::encode_hello_ack(&mut out, core.target.d() as u32, core.target.rows());
    if send_frame(&mut wstream, &mut out, core.cfg.max_frame).is_err() {
        core.metrics.write_errors.fetch_add(1, Ordering::Relaxed);
        return None;
    }
    Some((tenant, wstream))
}

/// Best-effort `Shed` frame on a connection being turned away.
fn refuse(core: &Arc<ServerCore>, wstream: &mut TcpStream, code: ErrorCode, msg: &str) {
    let mut out = Vec::with_capacity(64);
    begin_frame(&mut out);
    protocol::encode_shed(&mut out, code, msg);
    let _ = send_frame(wstream, &mut out, core.cfg.max_frame);
}

fn read_loop(core: &Arc<ServerCore>, stream: &mut TcpStream, tenant: &str, tx: Sender<Reply>) {
    // A response larger than max_frame would sever the connection at
    // write time; refuse the request instead, up front.
    let d = core.target.d().max(1);
    let row_cap = core
        .cfg
        .max_rows_per_request
        .min(core.cfg.max_frame.saturating_sub(64) / (d * 4 + 1));
    let table_rows = core.target.rows();
    let mut buf = Vec::with_capacity(4096);
    let mut idle = Duration::ZERO;
    loop {
        if core.state() == STOPPED {
            return;
        }
        let event = read_frame(
            stream,
            &mut buf,
            core.cfg.max_frame,
            READ_POLL,
            core.cfg.frame_timeout,
        );
        let frame = match event {
            Ok(FrameEvent::Idle) => {
                idle += READ_POLL;
                if idle >= core.cfg.idle_timeout {
                    core.metrics.slow_loris_closed.fetch_add(1, Ordering::Relaxed);
                    return;
                }
                continue;
            }
            Ok(FrameEvent::Eof) => return,
            Ok(FrameEvent::Frame(_)) => {
                idle = Duration::ZERO;
                match protocol::decode(&buf) {
                    Ok(f) => f,
                    Err(e) => {
                        // Undecodable bytes mean the stream is desynced:
                        // answer once, then close.
                        core.metrics.bad_frames.fetch_add(1, Ordering::Relaxed);
                        let _ = tx.send(Reply::Immediate {
                            req_id: 0,
                            code: ErrorCode::BadRequest,
                            msg: format!("malformed frame: {e:#}"),
                        });
                        return;
                    }
                }
            }
            Err(e) if e.kind() == std::io::ErrorKind::TimedOut => {
                core.metrics.slow_loris_closed.fetch_add(1, Ordering::Relaxed);
                return;
            }
            Err(e) if e.kind() == std::io::ErrorKind::InvalidData => {
                core.metrics.bad_frames.fetch_add(1, Ordering::Relaxed);
                return;
            }
            Err(_) => return,
        };
        let Frame::Lookup {
            req_id,
            deadline_ms,
            rows,
        } = frame
        else {
            // Only Lookup is valid after the handshake.
            core.metrics.bad_frames.fetch_add(1, Ordering::Relaxed);
            return;
        };
        core.metrics.requests.fetch_add(1, Ordering::Relaxed);
        if rows.len() > row_cap {
            let reply = Reply::Immediate {
                req_id,
                code: ErrorCode::BadRequest,
                msg: format!("request of {} rows exceeds cap {row_cap}", rows.len()),
            };
            if tx.send(reply).is_err() {
                return;
            }
            continue;
        }
        if let Some(&bad) = rows.iter().find(|&&r| r >= table_rows) {
            let reply = Reply::Immediate {
                req_id,
                code: ErrorCode::BadRequest,
                msg: format!("row {bad} out of range (table has {table_rows} rows)"),
            };
            if tx.send(reply).is_err() {
                return;
            }
            continue;
        }
        // In-flight is counted from *before* admission so a concurrent
        // drain cannot observe zero while a submit is mid-flight.
        let work = WorkGuard::new(core);
        let reply = match core.submit(tenant, Arc::new(rows), wire_deadline(deadline_ms)) {
            Ok(pending) => Reply::Ticket {
                req_id,
                pending,
                work,
            },
            Err((code, msg)) => {
                drop(work);
                Reply::Immediate { req_id, code, msg }
            }
        };
        if tx.send(reply).is_err() {
            return;
        }
    }
}

/// Single writer: resolves tickets in arrival order and owns every byte
/// written after the handshake.
fn write_loop(core: Arc<ServerCore>, mut stream: TcpStream, rx: Receiver<Reply>) {
    let d = core.target.d().max(1);
    let mut out = Vec::with_capacity(4096);
    while let Ok(reply) = rx.recv() {
        begin_frame(&mut out);
        // Held across the write: in-flight must not reach zero (and let
        // a drain declare victory) until the response is on the wire.
        let mut held: Option<WorkGuard> = None;
        match reply {
            Reply::Immediate { req_id, code, msg } => {
                core.metrics.responses_error.fetch_add(1, Ordering::Relaxed);
                protocol::encode_error(&mut out, req_id, code, &msg);
            }
            Reply::Ticket {
                req_id,
                pending,
                work,
            } => {
                held = Some(work);
                match pending.wait_outcome() {
                    Ok(Outcome::Full(data)) => {
                        core.metrics.responses_full.fetch_add(1, Ordering::Relaxed);
                        protocol::encode_full(&mut out, req_id, (data.len() / d) as u32, &data);
                        core.target.recycle(data);
                    }
                    Ok(Outcome::Partial { rows, valid }) => {
                        core.metrics.responses_partial.fetch_add(1, Ordering::Relaxed);
                        protocol::encode_partial(&mut out, req_id, &valid, &rows);
                        core.target.recycle(rows);
                    }
                    Err(e) => {
                        core.metrics.responses_error.fetch_add(1, Ordering::Relaxed);
                        let code = super::classify(&e);
                        protocol::encode_error(&mut out, req_id, code, &format!("{e:#}"));
                    }
                }
            }
        }
        let wrote = send_frame(&mut stream, &mut out, core.cfg.max_frame).is_ok();
        drop(held);
        if !wrote {
            core.metrics.write_errors.fetch_add(1, Ordering::Relaxed);
            // The peer is gone; remaining queue entries drop here,
            // releasing their tickets and in-flight guards.
            return;
        }
    }
}

//! The serving core and listener lifecycle.
//!
//! [`NetServer`] owns two nonblocking listeners (binary TCP + optional
//! HTTP), a shared [`ServerCore`] (admission state, gauges, counters),
//! and the three-state lifecycle the drain story hangs on:
//!
//! ```text
//!   Serving ──drain()──► Draining ──in_flight==0──► Stopped
//!     accept+serve         accept → Shed(draining)    backend shut down,
//!                          requests → Error(draining)  slabs released
//!                          in-flight tickets finish
//! ```
//!
//! Accept never blocks (2 ms poll) so state changes are honored
//! promptly, and a connection the server will not serve — over the
//! limit, or mid-drain — still gets an explicit `Shed` frame before the
//! close: remote clients can always tell refusal from failure.

use std::collections::HashMap;
use std::fmt;
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicU64, AtomicU8, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use anyhow::Context;

use crate::service::{GlobalAdmission, OverloadPolicy, Session, SessionConfig};

use super::codec::{begin_frame, send_frame};
use super::protocol::{self, ErrorCode};
use super::{classify, conn, http, Pending, Target};

/// Lifecycle states (stored in `ServerCore::state`).
pub(crate) const SERVING: u8 = 0;
pub(crate) const DRAINING: u8 = 1;
pub(crate) const STOPPED: u8 = 2;

/// Accept-loop poll period (listeners are nonblocking so they observe
/// lifecycle transitions between accepts).
const ACCEPT_POLL: Duration = Duration::from_millis(2);
/// Idle slice for connection readers: the bound on how stale a reader's
/// view of the lifecycle state can get.
pub(crate) const READ_POLL: Duration = Duration::from_millis(50);
/// How long `drain`/`shutdown` waits for connection threads to notice
/// `Stopped` and exit (a few read-poll slices is plenty).
const CONN_EXIT_WAIT: Duration = Duration::from_secs(2);

/// Tuning for the network edge.  Defaults are sized for loopback tests;
/// `serve-net` exposes the load-bearing ones as flags.
#[derive(Debug, Clone)]
pub struct NetConfig {
    /// Bind address for the binary channel (`127.0.0.1:0` = ephemeral).
    pub addr: String,
    /// Bind address for the HTTP channel (None = no HTTP listener).
    pub http_addr: Option<String>,
    /// Binary-channel connection limit (excess gets `Shed(ConnLimit)`).
    pub max_conns: usize,
    /// HTTP-channel connection limit (excess gets 503).
    pub max_http_conns: usize,
    /// Cross-tenant in-flight budget ([`GlobalAdmission`] capacity).
    pub global_slots: usize,
    /// Per-tenant in-flight budget (single-card path mints `Session`s).
    pub per_tenant_in_flight: usize,
    /// Row-count ceiling per `Lookup` (over it = `BadRequest`).
    pub max_rows_per_request: usize,
    /// Frame payload ceiling on both directions.
    pub max_frame: usize,
    /// Close a connection idle longer than this between frames.
    pub idle_timeout: Duration,
    /// Slow-loris bound: a started frame must complete within this.
    pub frame_timeout: Duration,
    /// Per-connection socket write timeout.
    pub write_timeout: Duration,
    /// The `Hello` must arrive within this after connect.
    pub hello_timeout: Duration,
}

impl Default for NetConfig {
    fn default() -> Self {
        Self {
            addr: "127.0.0.1:0".into(),
            http_addr: None,
            max_conns: 64,
            max_http_conns: 16,
            global_slots: 256,
            per_tenant_in_flight: 64,
            max_rows_per_request: 65_536,
            max_frame: protocol::DEFAULT_MAX_FRAME,
            idle_timeout: Duration::from_secs(30),
            frame_timeout: Duration::from_secs(2),
            write_timeout: Duration::from_secs(5),
            hello_timeout: Duration::from_secs(2),
        }
    }
}

/// Readiness hook for `/readyz`: wired by `serve-net` to backend
/// breaker/health state so orchestration stops routing to a degraded
/// edge before it starts failing requests.
pub type ReadyProbe = Box<dyn Fn() -> bool + Send + Sync>;

/// Edge counters (atomics; sampled into [`NetMetricsSnapshot`]).
#[derive(Default)]
pub(crate) struct NetMetrics {
    pub(crate) conns_accepted: AtomicU64,
    pub(crate) conns_shed: AtomicU64,
    pub(crate) hellos: AtomicU64,
    pub(crate) requests: AtomicU64,
    pub(crate) responses_full: AtomicU64,
    pub(crate) responses_partial: AtomicU64,
    pub(crate) responses_error: AtomicU64,
    pub(crate) shed_over_budget: AtomicU64,
    pub(crate) shed_draining: AtomicU64,
    pub(crate) bad_frames: AtomicU64,
    pub(crate) slow_loris_closed: AtomicU64,
    pub(crate) write_errors: AtomicU64,
    pub(crate) http_requests: AtomicU64,
}

/// Point-in-time view of the edge counters plus the live gauges.
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct NetMetricsSnapshot {
    pub conns_accepted: u64,
    pub conns_shed: u64,
    pub hellos: u64,
    pub requests: u64,
    pub responses_full: u64,
    pub responses_partial: u64,
    pub responses_error: u64,
    pub shed_over_budget: u64,
    pub shed_draining: u64,
    pub bad_frames: u64,
    pub slow_loris_closed: u64,
    pub write_errors: u64,
    pub http_requests: u64,
    pub conns_open: usize,
    pub in_flight: usize,
}

impl fmt::Display for NetMetricsSnapshot {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(
            f,
            "conns {} (shed {}, open {}) reqs {} (full {}, partial {}, err {}) \
             shed(budget {}, drain {}) bad-frames {} loris {} write-errs {} http {}",
            self.conns_accepted,
            self.conns_shed,
            self.conns_open,
            self.requests,
            self.responses_full,
            self.responses_partial,
            self.responses_error,
            self.shed_over_budget,
            self.shed_draining,
            self.bad_frames,
            self.slow_loris_closed,
            self.write_errors,
            self.http_requests,
        )
    }
}

/// What a `drain` call observed: whether every in-flight ticket
/// resolved inside the timeout, and what got refused meanwhile.
#[derive(Debug, Clone)]
pub struct DrainReport {
    /// True iff in-flight hit zero before the timeout.
    pub completed: bool,
    /// Time spent waiting for in-flight work.
    pub waited: Duration,
    /// In-flight requests when the drain started.
    pub in_flight_at_start: usize,
    /// Connections shed (with an explicit response) during the drain.
    pub refused_conns: u64,
}

/// State shared by both channels and every connection thread.
pub(crate) struct ServerCore {
    pub(crate) cfg: NetConfig,
    pub(crate) target: Target,
    pub(crate) state: AtomicU8,
    pub(crate) conns: AtomicUsize,
    pub(crate) http_conns: AtomicUsize,
    pub(crate) in_flight: AtomicUsize,
    pub(crate) metrics: NetMetrics,
    global: Arc<GlobalAdmission>,
    /// Single-card path: per-tenant sessions, minted on first `Hello`.
    sessions: Mutex<HashMap<String, Arc<Session>>>,
    /// Fleet path: tenant name -> admission registration.
    tenants: Mutex<HashMap<String, usize>>,
    ready: Option<ReadyProbe>,
}

impl ServerCore {
    pub(crate) fn state(&self) -> u8 {
        self.state.load(Ordering::Acquire)
    }

    pub(crate) fn serving(&self) -> bool {
        self.state() == SERVING
    }

    /// `/readyz`: serving *and* the backend probe (if any) agrees.
    pub(crate) fn ready(&self) -> bool {
        self.serving() && self.ready.as_ref().is_none_or(|probe| probe())
    }

    pub(crate) fn state_name(&self) -> &'static str {
        match self.state() {
            SERVING => "serving",
            DRAINING => "draining",
            _ => "stopped",
        }
    }

    pub(crate) fn snapshot(&self) -> NetMetricsSnapshot {
        let m = &self.metrics;
        let c = |a: &AtomicU64| a.load(Ordering::Relaxed);
        NetMetricsSnapshot {
            conns_accepted: c(&m.conns_accepted),
            conns_shed: c(&m.conns_shed),
            hellos: c(&m.hellos),
            requests: c(&m.requests),
            responses_full: c(&m.responses_full),
            responses_partial: c(&m.responses_partial),
            responses_error: c(&m.responses_error),
            shed_over_budget: c(&m.shed_over_budget),
            shed_draining: c(&m.shed_draining),
            bad_frames: c(&m.bad_frames),
            slow_loris_closed: c(&m.slow_loris_closed),
            write_errors: c(&m.write_errors),
            http_requests: c(&m.http_requests),
            conns_open: self.conns.load(Ordering::Relaxed)
                + self.http_conns.load(Ordering::Relaxed),
            in_flight: self.in_flight.load(Ordering::Acquire),
        }
    }

    /// Admit and submit one request for `tenant`.  Refusals come back as
    /// wire-ready `(code, message)` pairs — the connection survives; only
    /// the request is refused.
    pub(crate) fn submit(
        &self,
        tenant: &str,
        rows: Arc<Vec<u64>>,
        deadline: Option<Duration>,
    ) -> Result<Pending, (ErrorCode, String)> {
        if !self.serving() {
            self.metrics.shed_draining.fetch_add(1, Ordering::Relaxed);
            return Err((ErrorCode::Draining, "server draining".into()));
        }
        let out = match &self.target {
            Target::Single(_) => self
                .session(tenant)
                .submit_with_deadline(rows, deadline)
                .map(Pending::Single),
            Target::Fleet(fleet) => {
                let id = self.tenant_id(tenant);
                match GlobalAdmission::try_acquire(&self.global, id) {
                    None => Err(anyhow::anyhow!(
                        "tenant '{tenant}' denied by the global admission budget ({})",
                        self.global.capacity()
                    )),
                    Some(slot) => fleet
                        .submit(rows, deadline)
                        .map(|t| Pending::Fleet(t, Some(slot))),
                }
            }
        };
        out.map_err(|e| {
            let code = classify(&e);
            if code == ErrorCode::OverBudget {
                self.metrics.shed_over_budget.fetch_add(1, Ordering::Relaxed);
            }
            (code, format!("{e:#}"))
        })
    }

    fn session(&self, tenant: &str) -> Arc<Session> {
        let mut map = self.sessions.lock().unwrap();
        if let Some(s) = map.get(tenant) {
            return Arc::clone(s);
        }
        let Target::Single(service) = &self.target else {
            unreachable!("sessions are only minted for single-card targets");
        };
        let session = Arc::new(service.session_with_budget(
            tenant,
            SessionConfig {
                max_in_flight: self.cfg.per_tenant_in_flight,
                overload: OverloadPolicy::Reject,
                deadline: None,
            },
            &self.global,
            1.0,
        ));
        map.insert(tenant.to_string(), Arc::clone(&session));
        session
    }

    fn tenant_id(&self, tenant: &str) -> usize {
        let mut map = self.tenants.lock().unwrap();
        if let Some(&id) = map.get(tenant) {
            return id;
        }
        let id = self.global.register(tenant, 1.0);
        map.insert(tenant.to_string(), id);
        id
    }
}

/// The listener owner.  Dropping it stops the server (hard); prefer
/// [`NetServer::drain`] for the graceful path.
pub struct NetServer {
    core: Arc<ServerCore>,
    addr: SocketAddr,
    http_addr: Option<SocketAddr>,
    accepts: Vec<JoinHandle<()>>,
}

impl NetServer {
    /// Bind both channels and start accepting.
    pub fn start(target: Target, cfg: NetConfig) -> anyhow::Result<Self> {
        Self::start_with_probe(target, cfg, None)
    }

    /// [`NetServer::start`] with a readiness probe for `/readyz`.
    pub fn start_with_probe(
        target: Target,
        cfg: NetConfig,
        ready: Option<ReadyProbe>,
    ) -> anyhow::Result<Self> {
        let listener = TcpListener::bind(&cfg.addr)
            .with_context(|| format!("binding binary channel on {}", cfg.addr))?;
        listener.set_nonblocking(true).context("nonblocking accept")?;
        let addr = listener.local_addr().context("local_addr")?;
        let http_listener = match &cfg.http_addr {
            None => None,
            Some(a) => {
                let l = TcpListener::bind(a)
                    .with_context(|| format!("binding http channel on {a}"))?;
                l.set_nonblocking(true).context("nonblocking accept")?;
                Some(l)
            }
        };
        let http_addr = match &http_listener {
            None => None,
            Some(l) => Some(l.local_addr().context("local_addr")?),
        };
        let global = GlobalAdmission::new(cfg.global_slots);
        let core = Arc::new(ServerCore {
            cfg,
            target,
            state: AtomicU8::new(SERVING),
            conns: AtomicUsize::new(0),
            http_conns: AtomicUsize::new(0),
            in_flight: AtomicUsize::new(0),
            metrics: NetMetrics::default(),
            global,
            sessions: Mutex::new(HashMap::new()),
            tenants: Mutex::new(HashMap::new()),
            ready,
        });
        let mut accepts = Vec::new();
        let c = Arc::clone(&core);
        accepts.push(
            std::thread::Builder::new()
                .name("net-accept".into())
                .spawn(move || accept_loop(c, listener))
                .context("spawning accept thread")?,
        );
        if let Some(l) = http_listener {
            let c = Arc::clone(&core);
            accepts.push(
                std::thread::Builder::new()
                    .name("net-http-accept".into())
                    .spawn(move || http_accept_loop(c, l))
                    .context("spawning http accept thread")?,
            );
        }
        Ok(Self {
            core,
            addr,
            http_addr,
            accepts,
        })
    }

    /// Bound address of the binary channel.
    pub fn addr(&self) -> SocketAddr {
        self.addr
    }

    /// Bound address of the HTTP channel, if configured.
    pub fn http_addr(&self) -> Option<SocketAddr> {
        self.http_addr
    }

    pub fn metrics(&self) -> NetMetricsSnapshot {
        self.core.snapshot()
    }

    /// Requests admitted but not yet answered.
    pub fn in_flight(&self) -> usize {
        self.core.in_flight.load(Ordering::Acquire)
    }

    /// Graceful drain: stop accepting (new connections and new requests
    /// get explicit refusals), wait up to `timeout` for in-flight
    /// tickets to resolve, then stop and shut the backend down —
    /// releasing its slab pools.  Idempotent; returns what it observed.
    pub fn drain(&mut self, timeout: Duration) -> DrainReport {
        let _ = self.core.state.compare_exchange(
            SERVING,
            DRAINING,
            Ordering::AcqRel,
            Ordering::Acquire,
        );
        let shed_before = self.core.metrics.conns_shed.load(Ordering::Relaxed);
        let in_flight_at_start = self.core.in_flight.load(Ordering::Acquire);
        let start = Instant::now();
        while self.core.in_flight.load(Ordering::Acquire) > 0 && start.elapsed() < timeout {
            std::thread::sleep(ACCEPT_POLL);
        }
        let completed = self.core.in_flight.load(Ordering::Acquire) == 0;
        let waited = start.elapsed();
        let refused_conns = self.core.metrics.conns_shed.load(Ordering::Relaxed) - shed_before;
        self.halt();
        DrainReport {
            completed,
            waited,
            in_flight_at_start,
            refused_conns,
        }
    }

    /// Hard stop: no waiting for in-flight work (their tickets are
    /// dropped; admission guards release via RAII).  Prefer `drain`.
    pub fn shutdown(&mut self) {
        self.halt();
    }

    fn halt(&mut self) {
        self.core.state.store(STOPPED, Ordering::Release);
        let open = |core: &ServerCore| {
            core.conns.load(Ordering::Relaxed) + core.http_conns.load(Ordering::Relaxed)
        };
        let start = Instant::now();
        while open(&self.core) > 0 && start.elapsed() < CONN_EXIT_WAIT {
            std::thread::sleep(ACCEPT_POLL);
        }
        for h in self.accepts.drain(..) {
            let _ = h.join();
        }
        self.core.target.shutdown();
    }
}

impl Drop for NetServer {
    fn drop(&mut self) {
        self.halt();
    }
}

/// RAII decrement for a connection gauge (readers/HTTP threads exit on
/// panic paths too, so the gauge never leaks).
pub(crate) struct ConnGuard {
    gauge: Arc<ServerCore>,
    http: bool,
}

impl Drop for ConnGuard {
    fn drop(&mut self) {
        let g = if self.http {
            &self.gauge.http_conns
        } else {
            &self.gauge.conns
        };
        g.fetch_sub(1, Ordering::AcqRel);
    }
}

fn accept_loop(core: Arc<ServerCore>, listener: TcpListener) {
    loop {
        if core.state() == STOPPED {
            break;
        }
        match listener.accept() {
            Ok((stream, _peer)) => accept_binary(&core, stream),
            Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                std::thread::sleep(ACCEPT_POLL);
            }
            Err(_) => std::thread::sleep(ACCEPT_POLL),
        }
    }
}

fn accept_binary(core: &Arc<ServerCore>, mut stream: TcpStream) {
    core.metrics.conns_accepted.fetch_add(1, Ordering::Relaxed);
    if core.state() != SERVING {
        core.metrics.shed_draining.fetch_add(1, Ordering::Relaxed);
        shed_and_close(core, &mut stream, ErrorCode::Draining, "server draining");
        return;
    }
    if core.conns.fetch_add(1, Ordering::AcqRel) >= core.cfg.max_conns {
        core.conns.fetch_sub(1, Ordering::AcqRel);
        shed_and_close(
            core,
            &mut stream,
            ErrorCode::ConnLimit,
            "connection limit reached",
        );
        return;
    }
    let guard = ConnGuard {
        gauge: Arc::clone(core),
        http: false,
    };
    let c = Arc::clone(core);
    let spawned = std::thread::Builder::new()
        .name("net-conn".into())
        .spawn(move || conn::serve(c, stream, guard));
    if spawned.is_err() {
        // Spawn failure drops the closure, which drops the guard, so the
        // gauge stays honest; the connection closes without a shed frame
        // (thread exhaustion is a process-level emergency, not a
        // protocol event).
        core.metrics.write_errors.fetch_add(1, Ordering::Relaxed);
    }
}

/// Best-effort explicit refusal: one `Shed` frame, then close.  The
/// write gets a short timeout so a malicious peer cannot pin the accept
/// thread.
fn shed_and_close(core: &Arc<ServerCore>, stream: &mut TcpStream, code: ErrorCode, msg: &str) {
    core.metrics.conns_shed.fetch_add(1, Ordering::Relaxed);
    let _ = stream.set_write_timeout(Some(Duration::from_millis(200)));
    let mut out = Vec::with_capacity(64);
    begin_frame(&mut out);
    protocol::encode_shed(&mut out, code, msg);
    let _ = send_frame(stream, &mut out, core.cfg.max_frame);
}

fn http_accept_loop(core: Arc<ServerCore>, listener: TcpListener) {
    loop {
        if core.state() == STOPPED {
            break;
        }
        match listener.accept() {
            Ok((stream, _peer)) => accept_http(&core, stream),
            Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                std::thread::sleep(ACCEPT_POLL);
            }
            Err(_) => std::thread::sleep(ACCEPT_POLL),
        }
    }
}

fn accept_http(core: &Arc<ServerCore>, stream: TcpStream) {
    core.metrics.conns_accepted.fetch_add(1, Ordering::Relaxed);
    // HTTP connections are accepted even mid-drain: `/healthz` must keep
    // answering so operators can watch the drain; mutating requests are
    // refused inside the handler with a 503.
    if core.http_conns.fetch_add(1, Ordering::AcqRel) >= core.cfg.max_http_conns {
        core.http_conns.fetch_sub(1, Ordering::AcqRel);
        core.metrics.conns_shed.fetch_add(1, Ordering::Relaxed);
        http::shed_and_close(core, stream);
        return;
    }
    let guard = ConnGuard {
        gauge: Arc::clone(core),
        http: true,
    };
    let c = Arc::clone(core);
    let spawned = std::thread::Builder::new()
        .name("net-http".into())
        .spawn(move || http::serve(c, stream, guard));
    if spawned.is_err() {
        core.metrics.write_errors.fetch_add(1, Ordering::Relaxed);
    }
}

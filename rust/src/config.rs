//! Machine and simulation configuration.
//!
//! All hardware parameters of the simulated GPU live here, with presets
//! calibrated so the A100-SXM4-80GB preset reproduces the paper's measured
//! curves (see DESIGN.md §6 for the calibration derivation):
//!
//! * random 128 B coalesced reads over a TLB-resident region saturate at
//!   ~1.3 TB/s device-wide (paper Fig 1/6 plateau),
//! * a solo 8-SM resource group reaches ~120 GB/s and a 6-SM group ~90 GB/s
//!   (paper Fig 4),
//! * regions larger than the 64 GB per-group TLB reach collapse to the
//!   page-walker service rate (paper Fig 1 cliff).

/// Bytes in one GiB (the paper speaks in "GB" but means GiB-scale windows).
pub const GIB: u64 = 1 << 30;

/// One warp-coalesced access: 32 lanes x 32-bit words = 128 bytes.
pub const LINE_BYTES: u64 = 128;

/// Topology parameters: how many clusters exist physically and how many
/// survive yield harvesting.
#[derive(Debug, Clone, PartialEq)]
pub struct TopologyConfig {
    /// Physical GPCs on the die (A100: 8).
    pub physical_gpcs: usize,
    /// GPCs enabled after harvesting (A100: 7).
    pub enabled_gpcs: usize,
    /// TPCs per GPC physically (A100: 8).
    pub tpcs_per_gpc: usize,
    /// Total enabled TPCs across the device (A100: 54 -> 108 SMs).
    pub enabled_tpcs: usize,
    /// SMs per TPC (A100: 2).
    pub sms_per_tpc: usize,
    /// Seed for the card-specific SM-enumeration permutation.  Real cards
    /// differ ("this may vary card to card", paper §1.1); the probe must
    /// not rely on the enumeration order.
    pub smid_permutation_seed: u64,
}

impl TopologyConfig {
    pub fn a100(seed: u64) -> Self {
        Self {
            physical_gpcs: 8,
            enabled_gpcs: 7,
            tpcs_per_gpc: 8,
            enabled_tpcs: 54,
            sms_per_tpc: 2,
            smid_permutation_seed: seed,
        }
    }

    /// Total enabled SMs.
    pub fn sm_count(&self) -> usize {
        self.enabled_tpcs * self.sms_per_tpc
    }
}

/// TLB geometry for one SM resource group (half-GPC), plus the per-SM uTLB.
#[derive(Debug, Clone, PartialEq)]
pub struct TlbConfig {
    /// Page size in bytes (2 MiB on the simulated card).
    pub page_bytes: u64,
    /// Entries in the per-group TLB.  32768 x 2 MiB = 64 GiB reach — the
    /// quantity the whole paper is about.
    pub entries: usize,
    /// Associativity of the per-group TLB (entries/assoc sets, LRU).
    pub associativity: usize,
    /// Entries in the per-SM micro-TLB (fully associative, LRU).  0 disables.
    pub utlb_entries: usize,
    /// Latency of a group-TLB hit, ns.
    pub hit_ns: f64,
    /// Latency of one page walk, ns (service time at a walker).
    pub walk_ns: f64,
    /// Page walkers per group (k-server pool); misses queue here, and this
    /// service rate is what the Fig-1 cliff collapses onto.
    pub walkers_per_group: usize,
}

impl TlbConfig {
    pub fn a100() -> Self {
        Self {
            page_bytes: 2 * 1024 * 1024,
            entries: 32768,
            associativity: 8,
            utlb_entries: 32,
            hit_ns: 25.0,
            walk_ns: 500.0,
            walkers_per_group: 8,
        }
    }

    /// TLB reach in bytes.
    pub fn reach_bytes(&self) -> u64 {
        self.entries as u64 * self.page_bytes
    }

    pub fn sets(&self) -> usize {
        self.entries / self.associativity
    }
}

/// HBM + interconnect parameters.
#[derive(Debug, Clone, PartialEq)]
pub struct MemoryConfig {
    /// Total device memory, bytes (80 GiB preset).
    pub total_bytes: u64,
    /// Number of independent HBM channels (address-striped by line).
    pub channels: usize,
    /// Peak aggregate bandwidth, GB/s (A100 80GB: ~1935).
    pub peak_gbps: f64,
    /// Efficiency of a 128 B transaction (paper §2.1: 128 B random reads
    /// reach ~1300/1935; 256 B ~1400; 512 B ~1600).
    pub efficiency_128b: f64,
    /// Fixed HBM access latency, ns (row activation + on-die transit).
    pub base_latency_ns: f64,
    /// Per-group memory-port bandwidth, GB/s.  Slightly above what a full
    /// 8-SM group demands, so solo groups are SM-limited (Fig 4) but the
    /// port still shapes heavy intra-group contention.
    pub group_port_gbps: f64,
    /// Per-GPC hub bandwidth, GB/s.  Both half-GPC groups of one GPC share
    /// this; it is generously provisioned and only produces the *faint*
    /// background pattern of Fig 2.
    pub gpc_hub_gbps: f64,
}

impl MemoryConfig {
    pub fn a100_80gb() -> Self {
        Self {
            total_bytes: 80 * GIB,
            channels: 32,
            peak_gbps: 1935.0,
            efficiency_128b: 0.68,
            base_latency_ns: 350.0,
            group_port_gbps: 130.0,
            gpc_hub_gbps: 260.0,
        }
    }

    /// Effective per-channel bandwidth for a given transaction efficiency.
    pub fn channel_gbps(&self, efficiency: f64) -> f64 {
        self.peak_gbps * efficiency / self.channels as f64
    }

    /// Efficiency for a transaction of `bytes` (piecewise model of the
    /// paper's §2.1 aside: 128 B ≈ 0.68, 256 B ≈ 0.72, 512 B ≈ 0.83).
    pub fn txn_efficiency(&self, bytes: u64) -> f64 {
        match bytes {
            0..=128 => self.efficiency_128b,
            129..=256 => 0.72,
            257..=512 => 0.83,
            _ => 0.90,
        }
    }
}

/// Per-SM execution parameters.
#[derive(Debug, Clone, PartialEq)]
pub struct SmConfig {
    /// Outstanding line accesses one SM keeps in flight (latency hiding by
    /// resident warps; each warp has one coalesced access outstanding).
    pub outstanding: usize,
    /// Minimum interval between successive issues from one SM, ns.
    pub issue_interval_ns: f64,
}

impl SmConfig {
    pub fn a100() -> Self {
        Self {
            outstanding: 48,
            issue_interval_ns: 0.7,
        }
    }
}

/// Everything about the simulated machine.
#[derive(Debug, Clone, PartialEq)]
pub struct MachineConfig {
    pub topology: TopologyConfig,
    pub tlb: TlbConfig,
    pub memory: MemoryConfig,
    pub sm: SmConfig,
}

impl MachineConfig {
    /// The card the paper measured: SXM4-80GB.
    pub fn a100_80gb() -> Self {
        Self {
            topology: TopologyConfig::a100(0xA100),
            tlb: TlbConfig::a100(),
            memory: MemoryConfig::a100_80gb(),
            sm: SmConfig::a100(),
        }
    }

    /// The 40 GB launch variant (same groups, half the memory; the whole
    /// memory fits under one TLB reach, so the paper's problem never
    /// arises — useful as a control in tests and ablations).
    pub fn a100_40gb() -> Self {
        let mut c = Self::a100_80gb();
        c.memory.total_bytes = 40 * GIB;
        c
    }

    /// A tiny machine for fast unit tests: 2 GPCs / 4 groups / 12 SMs and a
    /// scaled-down TLB so tests exercise the cliff in milliseconds.
    pub fn tiny_test() -> Self {
        Self {
            topology: TopologyConfig {
                physical_gpcs: 2,
                enabled_gpcs: 2,
                tpcs_per_gpc: 4,
                enabled_tpcs: 6,
                sms_per_tpc: 2,
                smid_permutation_seed: 7,
            },
            tlb: TlbConfig {
                page_bytes: 1 << 16, // 64 KiB pages
                entries: 256,        // reach = 16 MiB
                associativity: 4,
                utlb_entries: 8,
                hit_ns: 25.0,
                walk_ns: 500.0,
                walkers_per_group: 4,
            },
            memory: MemoryConfig {
                total_bytes: 64 << 20, // 64 MiB
                channels: 8,
                peak_gbps: 1935.0,
                efficiency_128b: 0.68,
                base_latency_ns: 350.0,
                group_port_gbps: 130.0,
                gpc_hub_gbps: 260.0,
            },
            sm: SmConfig::a100(),
        }
    }

    pub fn validate(&self) -> Result<(), String> {
        if self.topology.enabled_gpcs == 0
            || self.topology.enabled_gpcs > self.topology.physical_gpcs
        {
            return Err("enabled_gpcs must be in 1..=physical_gpcs".into());
        }
        let max_tpcs = self.topology.enabled_gpcs * self.topology.tpcs_per_gpc;
        if self.topology.enabled_tpcs == 0 || self.topology.enabled_tpcs > max_tpcs {
            return Err(format!(
                "enabled_tpcs {} must be in 1..={max_tpcs}",
                self.topology.enabled_tpcs
            ));
        }
        // Every enabled GPC must keep >= 1 TPC per half for the half-GPC
        // grouping to be well defined.
        if self.topology.enabled_tpcs < self.topology.enabled_gpcs * 2 {
            return Err("need at least 2 TPCs per enabled GPC".into());
        }
        if self.tlb.entries == 0 || self.tlb.associativity == 0 {
            return Err("tlb entries/associativity must be nonzero".into());
        }
        if self.tlb.entries % self.tlb.associativity != 0 {
            return Err("tlb entries must be divisible by associativity".into());
        }
        if !self.tlb.page_bytes.is_power_of_two() {
            return Err("page_bytes must be a power of two".into());
        }
        if self.memory.total_bytes % self.tlb.page_bytes != 0 {
            return Err("total_bytes must be page-aligned".into());
        }
        if self.memory.channels == 0 {
            return Err("need at least one HBM channel".into());
        }
        if self.sm.outstanding == 0 {
            return Err("sm.outstanding must be nonzero".into());
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn a100_preset_validates() {
        MachineConfig::a100_80gb().validate().unwrap();
        MachineConfig::a100_40gb().validate().unwrap();
        MachineConfig::tiny_test().validate().unwrap();
    }

    #[test]
    fn a100_reach_is_64_gib() {
        assert_eq!(TlbConfig::a100().reach_bytes(), 64 * GIB);
    }

    #[test]
    fn a100_sm_count_is_108() {
        assert_eq!(TopologyConfig::a100(0).sm_count(), 108);
    }

    #[test]
    fn channel_bandwidth_sums_to_effective_peak() {
        let m = MemoryConfig::a100_80gb();
        let agg = m.channel_gbps(m.efficiency_128b) * m.channels as f64;
        assert!((agg - 1935.0 * 0.68).abs() < 1e-9);
    }

    #[test]
    fn txn_efficiency_monotone() {
        let m = MemoryConfig::a100_80gb();
        assert!(m.txn_efficiency(128) < m.txn_efficiency(256));
        assert!(m.txn_efficiency(256) < m.txn_efficiency(512));
        assert!(m.txn_efficiency(512) < m.txn_efficiency(1024));
    }

    #[test]
    fn validate_rejects_bad_configs() {
        let mut c = MachineConfig::a100_80gb();
        c.tlb.associativity = 3;
        c.tlb.entries = 32768;
        assert!(c.validate().is_err());

        let mut c = MachineConfig::a100_80gb();
        c.topology.enabled_tpcs = 0;
        assert!(c.validate().is_err());

        let mut c = MachineConfig::a100_80gb();
        c.tlb.page_bytes = 3 << 20;
        assert!(c.validate().is_err());

        let mut c = MachineConfig::a100_80gb();
        c.memory.channels = 0;
        assert!(c.validate().is_err());
    }

}

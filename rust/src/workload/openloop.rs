//! Open-loop load generation: Poisson arrivals at a configured offered
//! load, independent of service completions — the honest way to measure a
//! server's latency-throughput curve (closed-loop clients self-throttle
//! and hide queueing collapse).
//!
//! Backend-agnostic: drives any [`LoadTarget`] — a single [`Service`]
//! (sim-backed for hermetic QPS sweeps via `a100win bench-serve`,
//! PJRT-backed when artifacts exist) or a whole [`FleetService`]
//! (`bench-serve --cards N`, where the repartitioning control plane
//! migrates rows mid-sweep).

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

use crate::service::{FleetService, Service};
use crate::util::rng::Rng;
use crate::workload::RequestGen;

/// Anything the open-loop driver can aim at: submit one request, block
/// until it resolves.  Implementations must be shareable across the
/// per-arrival threads.
pub trait LoadTarget: Sync {
    fn run_request(&self, rows: Arc<Vec<u64>>, deadline: Option<Duration>) -> anyhow::Result<()>;
}

impl LoadTarget for Service {
    fn run_request(&self, rows: Arc<Vec<u64>>, deadline: Option<Duration>) -> anyhow::Result<()> {
        let out = self.submit(rows, deadline)?.wait()?;
        // Return the output slab to the backend pool: the sweep measures
        // the serving pipeline, not the benchmark client's allocator.
        self.recycle(out);
        Ok(())
    }
}

impl LoadTarget for FleetService {
    fn run_request(&self, rows: Arc<Vec<u64>>, deadline: Option<Duration>) -> anyhow::Result<()> {
        let out = self.submit(rows, deadline)?.wait()?;
        self.recycle(out);
        Ok(())
    }
}

/// Remote counterpart: the same sweep over loopback (or real) TCP via a
/// pooled binary-protocol client (`bench-serve --remote`).  Uses the
/// pool's pinned path so the *client's* allocator stays out of the
/// measurement, mirroring the `recycle` discipline above.
impl LoadTarget for crate::net::RemotePool {
    fn run_request(&self, rows: Arc<Vec<u64>>, deadline: Option<Duration>) -> anyhow::Result<()> {
        self.request_pinned(&rows, deadline)
    }
}

/// One point on the latency-throughput curve.
#[derive(Debug, Clone)]
pub struct LoadPoint {
    /// Offered load, requests/s.
    pub offered_rps: f64,
    /// Achieved goodput, requests/s.
    pub achieved_rps: f64,
    pub mean_latency_us: f64,
    pub p99_latency_us: u64,
    /// Requests dropped at the in-flight cap (the system fell behind the
    /// arrival clock).
    pub dropped: u64,
    pub errors: u64,
}

/// Open-loop driver configuration.
#[derive(Debug, Clone)]
pub struct OpenLoopConfig {
    pub duration: Duration,
    /// In-flight cap: arrivals beyond it are counted as dropped (an open
    /// system would queue unboundedly; the cap keeps runs finite).
    pub max_in_flight: usize,
    /// Deadline attached to every request (None = unbounded); expiries
    /// count as errors.
    pub deadline: Option<Duration>,
    /// Cap on generated arrivals (None = duration-bounded only) — CI smoke
    /// runs bound work by request count, not wall clock.
    pub max_requests: Option<u64>,
    pub seed: u64,
}

impl Default for OpenLoopConfig {
    fn default() -> Self {
        Self {
            duration: Duration::from_millis(800),
            max_in_flight: 256,
            deadline: None,
            max_requests: None,
            seed: 7,
        }
    }
}

/// Drive the target at `offered_rps` with Poisson arrivals; requests are
/// executed by per-arrival threads so arrivals never block on service
/// (open loop), up to the in-flight cap.
pub fn drive<T: LoadTarget + ?Sized>(
    service: &T,
    gen: &mut RequestGen,
    offered_rps: f64,
    cfg: &OpenLoopConfig,
) -> LoadPoint {
    assert!(offered_rps > 0.0);
    let mut rng = Rng::seed_from_u64(cfg.seed);
    // Pre-draw the arrival schedule and payloads (shared by Arc: the spawn
    // path never copies indices).
    let mut arrivals: Vec<(Duration, Arc<Vec<u64>>)> = Vec::new();
    let mut t = 0.0f64;
    loop {
        // Exponential inter-arrival.
        let u = rng.gen_f64().max(1e-12);
        t += -u.ln() / offered_rps;
        if t > cfg.duration.as_secs_f64() {
            break;
        }
        if cfg
            .max_requests
            .is_some_and(|cap| arrivals.len() as u64 >= cap)
        {
            break;
        }
        arrivals.push((Duration::from_secs_f64(t), Arc::new(gen.next_request())));
    }

    let in_flight = Arc::new(AtomicU64::new(0));
    let dropped = Arc::new(AtomicU64::new(0));
    let errors = Arc::new(AtomicU64::new(0));
    let done = Arc::new(AtomicU64::new(0));
    let lat_sum_us = Arc::new(AtomicU64::new(0));
    let lat_max_us = Arc::new(AtomicU64::new(0));
    // Coarse p99 via a fixed histogram (1 µs..16 s, log2 buckets).
    let hist: Arc<Vec<AtomicU64>> = Arc::new((0..34).map(|_| AtomicU64::new(0)).collect());

    let start = Instant::now();
    std::thread::scope(|s| {
        for (at, rows) in arrivals.iter() {
            // Arrival clock.
            let now = start.elapsed();
            if *at > now {
                std::thread::sleep(*at - now);
            }
            if in_flight.load(Ordering::Relaxed) >= cfg.max_in_flight as u64 {
                dropped.fetch_add(1, Ordering::Relaxed);
                continue;
            }
            in_flight.fetch_add(1, Ordering::Relaxed);
            let in_flight = Arc::clone(&in_flight);
            let errors = Arc::clone(&errors);
            let done = Arc::clone(&done);
            let lat_sum_us = Arc::clone(&lat_sum_us);
            let lat_max_us = Arc::clone(&lat_max_us);
            let hist = Arc::clone(&hist);
            let rows = Arc::clone(rows);
            let deadline = cfg.deadline;
            s.spawn(move || {
                let t0 = Instant::now();
                let result = service.run_request(rows, deadline);
                match result {
                    Ok(()) => {
                        let us = t0.elapsed().as_micros() as u64;
                        lat_sum_us.fetch_add(us, Ordering::Relaxed);
                        lat_max_us.fetch_max(us, Ordering::Relaxed);
                        let b = (64 - us.max(1).leading_zeros() as usize - 1).min(33);
                        hist[b].fetch_add(1, Ordering::Relaxed);
                        done.fetch_add(1, Ordering::Relaxed);
                    }
                    Err(_) => {
                        errors.fetch_add(1, Ordering::Relaxed);
                    }
                }
                in_flight.fetch_sub(1, Ordering::Relaxed);
            });
        }
    });
    let wall = start.elapsed().as_secs_f64();

    let completed = done.load(Ordering::Relaxed);
    let p99 = {
        let want = (completed as f64 * 0.99).ceil() as u64;
        let mut acc = 0;
        let mut val = lat_max_us.load(Ordering::Relaxed);
        for (i, b) in hist.iter().enumerate() {
            acc += b.load(Ordering::Relaxed);
            if acc >= want && want > 0 {
                val = 1u64 << (i + 1);
                break;
            }
        }
        val
    };
    LoadPoint {
        offered_rps,
        achieved_rps: completed as f64 / wall,
        mean_latency_us: if completed > 0 {
            lat_sum_us.load(Ordering::Relaxed) as f64 / completed as f64
        } else {
            0.0
        },
        p99_latency_us: p99,
        dropped: dropped.load(Ordering::Relaxed),
        errors: errors.load(Ordering::Relaxed),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn poisson_schedule_matches_offered_rate() {
        // Statistical check on the arrival generator without a server.
        let mut rng = Rng::seed_from_u64(1);
        let rate = 5_000.0f64;
        let horizon = 2.0f64;
        let mut n = 0u64;
        let mut t = 0.0;
        loop {
            let u = rng.gen_f64().max(1e-12);
            t += -u.ln() / rate;
            if t > horizon {
                break;
            }
            n += 1;
        }
        let expected = rate * horizon;
        assert!(
            (n as f64 - expected).abs() < expected * 0.05,
            "{n} arrivals vs expected {expected}"
        );
    }
}

//! Request-trace capture and replay.
//!
//! Plain text format, one request per line: comma-separated row indices.
//! Lets a workload observed in one run (or authored by hand) be replayed
//! byte-identically in benches and regression tests.

use std::io::{BufRead, Write};
use std::path::Path;

use anyhow::Context;

#[derive(Debug, Clone, PartialEq, Eq, Default)]
pub struct Trace {
    pub requests: Vec<Vec<u64>>,
}

impl Trace {
    pub fn new() -> Self {
        Self::default()
    }

    /// Record from a generator.
    pub fn capture(gen: &mut crate::workload::RequestGen, requests: usize) -> Self {
        Self {
            requests: (0..requests).map(|_| gen.next_request()).collect(),
        }
    }

    pub fn total_rows(&self) -> usize {
        self.requests.iter().map(|r| r.len()).sum()
    }

    pub fn save(&self, path: &Path) -> anyhow::Result<()> {
        let mut f = std::io::BufWriter::new(
            std::fs::File::create(path).with_context(|| format!("create {}", path.display()))?,
        );
        for req in &self.requests {
            let line: Vec<String> = req.iter().map(|r| r.to_string()).collect();
            writeln!(f, "{}", line.join(","))?;
        }
        Ok(())
    }

    pub fn load(path: &Path) -> anyhow::Result<Self> {
        let f = std::fs::File::open(path).with_context(|| format!("open {}", path.display()))?;
        let mut requests = Vec::new();
        for (ln, line) in std::io::BufReader::new(f).lines().enumerate() {
            let line = line?;
            let line = line.trim();
            if line.is_empty() || line.starts_with('#') {
                continue;
            }
            let req = line
                .split(',')
                .map(|t| {
                    t.trim()
                        .parse::<u64>()
                        .with_context(|| format!("line {}: bad index '{t}'", ln + 1))
                })
                .collect::<anyhow::Result<Vec<u64>>>()?;
            requests.push(req);
        }
        Ok(Self { requests })
    }

    /// Iterate in a loop (for fixed-duration replay).
    pub fn cycle(&self) -> impl Iterator<Item = &Vec<u64>> + '_ {
        self.requests.iter().cycle()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::{RequestGen, WorkloadSpec};

    #[test]
    fn capture_and_roundtrip() {
        let mut g = RequestGen::new(WorkloadSpec::uniform(1000, 16, 4));
        let t = Trace::capture(&mut g, 25);
        assert_eq!(t.requests.len(), 25);
        assert_eq!(t.total_rows(), 400);

        let dir = std::env::temp_dir().join(format!("a100win-trace-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("t.trace");
        t.save(&path).unwrap();
        let back = Trace::load(&path).unwrap();
        assert_eq!(t, back);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn load_skips_comments_and_blanks() {
        let dir = std::env::temp_dir().join(format!("a100win-trace2-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("t.trace");
        std::fs::write(&path, "# header\n1,2,3\n\n4\n").unwrap();
        let t = Trace::load(&path).unwrap();
        assert_eq!(t.requests, vec![vec![1, 2, 3], vec![4]]);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn load_rejects_garbage() {
        let dir = std::env::temp_dir().join(format!("a100win-trace3-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("t.trace");
        std::fs::write(&path, "1,x,3\n").unwrap();
        assert!(Trace::load(&path).is_err());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn cycle_repeats() {
        let t = Trace {
            requests: vec![vec![1], vec![2]],
        };
        let v: Vec<u64> = t.cycle().take(5).map(|r| r[0]).collect();
        assert_eq!(v, vec![1, 2, 1, 2, 1]);
    }
}

//! Synthetic request generators.
//!
//! Three flavors of skew matter to the serving stack and they are *not*
//! the same thing:
//!
//! * [`Distribution::Zipf`] — zipf over row *rank*, rank 0 = row 0: hot
//!   rows cluster at the front of the table, so the leading windows absorb
//!   most traffic.  This is the **window-skew** stressor the adaptive
//!   placer rebalances under (`a100win bench-serve --skew zipf:1.1`).
//! * [`Distribution::ZipfScattered`] — the same rank skew, but hot ranks
//!   are hashed over the whole table: row-level skew with near-uniform
//!   per-window load (hot embedding rows in a shuffled table).  A
//!   window-rebalancer can't (and shouldn't) react to it.
//! * [`Distribution::Drift`] — a **moving** hotspot: the inner
//!   distribution's row space is rotated by a third of the table every
//!   `period` requests, so yesterday's hot window goes cold and a static
//!   (or converged) placement is wrong again.  This is the repartitioning
//!   control plane's stressor (`a100win bench-serve --skew-drift
//!   drift:zipf:1.1:2000`).

use crate::util::rng::Rng;

/// Index distribution over the table's rows.
#[derive(Debug, Clone, PartialEq)]
pub enum Distribution {
    /// The paper's benchmark: uniform random rows.
    Uniform,
    /// Zipf over row rank, unscattered: row 0 hottest, so low windows run
    /// hot (window-level skew).  Valid for any `theta > 0` (bounded
    /// continuous-rank inversion; `theta = 1` handled separately).
    Zipf { theta: f64 },
    /// Zipf rank skew scattered pseudo-randomly over the table: hot *rows*
    /// without hot *windows*.
    ZipfScattered { theta: f64 },
    /// Sequential scan (control: TLB-friendly).
    Sequential,
    /// Rotating hotspot: draw from `inner`, then shift the row space by a
    /// third of the table once per `period` requests (drift cannot nest).
    Drift {
        inner: Box<Distribution>,
        period: u64,
    },
}

impl Distribution {
    /// Parse a CLI skew spec: `uniform`, `zipf:<theta>`,
    /// `zipf-scattered:<theta>`, `sequential`, or
    /// `drift:<inner-spec>:<period>` (e.g. `drift:zipf:1.1:5000`).
    pub fn parse(s: &str) -> anyhow::Result<Self> {
        let theta_of = |spec: &str, v: &str| -> anyhow::Result<f64> {
            let theta: f64 = v
                .parse()
                .map_err(|_| anyhow::anyhow!("{spec} expects a number, got '{v}'"))?;
            // NB: a plain `theta <= 0.0` admits NaN (every comparison with
            // NaN is false), which would degenerate into a row-0 point mass.
            if !theta.is_finite() || theta <= 0.0 {
                anyhow::bail!("{spec} theta must be a finite number > 0, got {theta}");
            }
            Ok(theta)
        };
        match s.split_once(':') {
            None => match s {
                "uniform" => Ok(Self::Uniform),
                "sequential" => Ok(Self::Sequential),
                _ => anyhow::bail!(
                    "unknown skew '{s}' \
                     (uniform|zipf:<theta>|zipf-scattered:<theta>|sequential|\
                      drift:<skew>:<period>)"
                ),
            },
            Some(("zipf", v)) => Ok(Self::Zipf {
                theta: theta_of("zipf", v)?,
            }),
            Some(("zipf-scattered", v)) => Ok(Self::ZipfScattered {
                theta: theta_of("zipf-scattered", v)?,
            }),
            Some(("drift", rest)) => {
                let (inner_spec, period_str) = rest.rsplit_once(':').ok_or_else(|| {
                    anyhow::anyhow!("drift expects drift:<skew>:<period>, got 'drift:{rest}'")
                })?;
                if inner_spec.starts_with("drift") {
                    anyhow::bail!("drift cannot nest");
                }
                let period: u64 = period_str.parse().map_err(|_| {
                    anyhow::anyhow!("drift period must be a number, got '{period_str}'")
                })?;
                if period == 0 {
                    anyhow::bail!("drift period must be > 0");
                }
                Ok(Self::Drift {
                    inner: Box::new(Self::parse(inner_spec)?),
                    period,
                })
            }
            Some((other, _)) => anyhow::bail!(
                "unknown skew '{other}' \
                 (uniform|zipf:<theta>|zipf-scattered:<theta>|sequential|drift:<skew>:<period>)"
            ),
        }
    }
}

/// Shape of the request stream.
#[derive(Debug, Clone)]
pub struct WorkloadSpec {
    pub total_rows: u64,
    pub distribution: Distribution,
    /// Rows per request (min..=max, drawn uniformly).
    pub request_rows: (usize, usize),
    pub seed: u64,
}

impl WorkloadSpec {
    pub fn uniform(total_rows: u64, request_rows: usize, seed: u64) -> Self {
        Self {
            total_rows,
            distribution: Distribution::Uniform,
            request_rows: (request_rows, request_rows),
            seed,
        }
    }
}

/// The drift-normalized base draw (no nesting, all-Copy payloads) so the
/// request hot path never matches through a `Box`.
#[derive(Debug, Clone, Copy)]
enum BaseDist {
    Uniform,
    Sequential,
    Zipf(f64),
    ZipfScattered(f64),
}

/// Stateful generator producing one request (a row-index batch) at a time.
#[derive(Debug, Clone)]
pub struct RequestGen {
    spec: WorkloadSpec,
    rng: Rng,
    cursor: u64,
    /// Requests generated so far (the drift rotation clock).
    requests: u64,
    base: BaseDist,
    /// `Some(period)` when the spec is [`Distribution::Drift`].
    drift_period: Option<u64>,
}

impl RequestGen {
    pub fn new(spec: WorkloadSpec) -> Self {
        assert!(spec.total_rows > 0);
        assert!(spec.request_rows.0 >= 1 && spec.request_rows.0 <= spec.request_rows.1);
        let base_of = |d: &Distribution| match d {
            Distribution::Uniform => BaseDist::Uniform,
            Distribution::Sequential => BaseDist::Sequential,
            Distribution::Zipf { theta } => BaseDist::Zipf(*theta),
            Distribution::ZipfScattered { theta } => BaseDist::ZipfScattered(*theta),
            Distribution::Drift { .. } => panic!("drift cannot nest"),
        };
        let (base, drift_period) = match &spec.distribution {
            Distribution::Drift { inner, period } => (base_of(inner), Some((*period).max(1))),
            other => (base_of(other), None),
        };
        let rng = Rng::seed_from_u64(spec.seed);
        Self {
            spec,
            rng,
            cursor: 0,
            requests: 0,
            base,
            drift_period,
        }
    }

    /// Rows the current drift rotation shifts every draw by (0 without
    /// drift): a third of the table, so the hot front lands in a
    /// different window each period.
    pub fn drift_offset(&self) -> u64 {
        let n = self.spec.total_rows;
        match self.drift_period {
            None => 0,
            Some(period) => {
                let step = n.div_ceil(3).max(1);
                let k = self.requests / period;
                ((k as u128 * step as u128) % n as u128) as u64
            }
        }
    }

    pub fn next_request(&mut self) -> Vec<u64> {
        let (lo, hi) = self.spec.request_rows;
        let len = if lo == hi {
            lo
        } else {
            lo + self.rng.gen_index(hi - lo + 1)
        };
        let req = (0..len).map(|_| self.next_row()).collect();
        self.requests += 1;
        req
    }

    fn next_row(&mut self) -> u64 {
        let n = self.spec.total_rows;
        let raw = match self.base {
            BaseDist::Uniform => self.rng.gen_range(n),
            BaseDist::Sequential => {
                let r = self.cursor % n;
                self.cursor += 1;
                r
            }
            BaseDist::Zipf(theta) => self.zipf_rank(theta),
            BaseDist::ZipfScattered(theta) => {
                // Fibonacci-hash the rank over the table: row-level skew,
                // window-uniform load.
                self.zipf_rank(theta).wrapping_mul(0x9E37_79B9_7F4A_7C15) % n
            }
        };
        let offset = self.drift_offset();
        if offset == 0 {
            raw
        } else {
            ((raw as u128 + offset as u128) % n as u128) as u64
        }
    }

    /// Bounded zipf(θ) rank in `[0, n)` by continuous inverse-CDF: the
    /// rank density ∝ (1+x)^(-θ) on [0, n], inverted exactly for θ ≠ 1 and
    /// via the log form at θ = 1 — valid for θ both below and above 1
    /// (the prior `n·u^(1/(1-θ))` approximation degenerated for θ ≥ 1).
    fn zipf_rank(&mut self, theta: f64) -> u64 {
        let n = self.spec.total_rows as f64;
        let u = self.rng.gen_f64().clamp(1e-12, 1.0);
        let x = if (theta - 1.0).abs() < 1e-9 {
            // F(x) = ln(1+x)/ln(1+n)
            (1.0 + n).powf(u) - 1.0
        } else {
            // F(x) = ((1+x)^(1-θ) − 1) / ((1+n)^(1-θ) − 1)
            let p = 1.0 - theta;
            (1.0 + u * ((1.0 + n).powf(p) - 1.0)).powf(1.0 / p) - 1.0
        };
        (x as u64).min(self.spec.total_rows - 1)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn uniform_in_range_and_fixed_len() {
        let mut g = RequestGen::new(WorkloadSpec::uniform(1000, 64, 1));
        for _ in 0..50 {
            let req = g.next_request();
            assert_eq!(req.len(), 64);
            assert!(req.iter().all(|&r| r < 1000));
        }
    }

    #[test]
    fn variable_request_sizes() {
        let mut g = RequestGen::new(WorkloadSpec {
            total_rows: 100,
            distribution: Distribution::Uniform,
            request_rows: (1, 10),
            seed: 2,
        });
        let sizes: Vec<usize> = (0..200).map(|_| g.next_request().len()).collect();
        assert!(sizes.iter().all(|&s| (1..=10).contains(&s)));
        assert!(sizes.iter().collect::<std::collections::HashSet<_>>().len() > 3);
    }

    #[test]
    fn sequential_wraps() {
        let mut g = RequestGen::new(WorkloadSpec {
            total_rows: 5,
            distribution: Distribution::Sequential,
            request_rows: (7, 7),
            seed: 0,
        });
        assert_eq!(g.next_request(), vec![0, 1, 2, 3, 4, 0, 1]);
    }

    #[test]
    fn zipf_is_skewed() {
        let mut g = RequestGen::new(WorkloadSpec {
            total_rows: 10_000,
            distribution: Distribution::Zipf { theta: 0.99 },
            request_rows: (1, 1),
            seed: 3,
        });
        let mut counts = std::collections::HashMap::new();
        for _ in 0..20_000 {
            *counts.entry(g.next_request()[0]).or_insert(0u32) += 1;
        }
        let max = *counts.values().max().unwrap();
        assert!(max > 200, "hottest row only {max} hits");
        assert!(counts.len() < 9_000);
    }

    /// Front-of-table concentration for a distribution, as the fraction of
    /// draws landing in the first half of the row space.
    fn front_half_fraction(dist: Distribution, n: u64, draws: usize) -> f64 {
        let mut g = RequestGen::new(WorkloadSpec {
            total_rows: n,
            distribution: dist,
            request_rows: (1, 1),
            seed: 5,
        });
        let hits = (0..draws).filter(|_| g.next_request()[0] < n / 2).count();
        hits as f64 / draws as f64
    }

    #[test]
    fn zipf_above_one_skews_windows_but_covers_table() {
        // theta > 1 used to degenerate under the old inverse-power
        // approximation; the bounded inversion must stay well-defined:
        // heavy front-half concentration, yet not a single point mass.
        let mut g = RequestGen::new(WorkloadSpec {
            total_rows: 65_536,
            distribution: Distribution::Zipf { theta: 1.1 },
            request_rows: (1, 1),
            seed: 4,
        });
        let mut distinct = std::collections::HashSet::new();
        let mut back_half = 0u32;
        for _ in 0..20_000 {
            let r = g.next_request()[0];
            assert!(r < 65_536);
            distinct.insert(r);
            if r >= 32_768 {
                back_half += 1;
            }
        }
        assert!(distinct.len() > 100, "degenerate: {} rows", distinct.len());
        assert!(back_half > 0, "tail never sampled");
        let front = front_half_fraction(Distribution::Zipf { theta: 1.1 }, 65_536, 20_000);
        assert!(front > 0.9, "window skew too weak: {front}");
    }

    #[test]
    fn scattered_zipf_is_window_uniform() {
        // Same rank skew, hashed over the table: per-half load near 50/50.
        let front =
            front_half_fraction(Distribution::ZipfScattered { theta: 1.1 }, 65_536, 20_000);
        assert!((front - 0.5).abs() < 0.1, "scatter failed: {front}");
    }

    #[test]
    fn skew_spec_parsing() {
        assert_eq!(Distribution::parse("uniform").unwrap(), Distribution::Uniform);
        assert_eq!(
            Distribution::parse("sequential").unwrap(),
            Distribution::Sequential
        );
        assert_eq!(
            Distribution::parse("zipf:1.1").unwrap(),
            Distribution::Zipf { theta: 1.1 }
        );
        assert_eq!(
            Distribution::parse("zipf-scattered:0.9").unwrap(),
            Distribution::ZipfScattered { theta: 0.9 }
        );
        assert_eq!(
            Distribution::parse("drift:zipf:1.1:5000").unwrap(),
            Distribution::Drift {
                inner: Box::new(Distribution::Zipf { theta: 1.1 }),
                period: 5000
            }
        );
        assert_eq!(
            Distribution::parse("drift:uniform:10").unwrap(),
            Distribution::Drift {
                inner: Box::new(Distribution::Uniform),
                period: 10
            }
        );
        assert!(Distribution::parse("zipf:0").is_err());
        assert!(Distribution::parse("zipf:nan").is_err());
        assert!(Distribution::parse("zipf:inf").is_err());
        assert!(Distribution::parse("zipf:abc").is_err());
        assert!(Distribution::parse("pareto:2").is_err());
        assert!(Distribution::parse("bogus").is_err());
        assert!(Distribution::parse("drift:zipf:1.1:0").is_err());
        assert!(Distribution::parse("drift:zipf:1.1").is_err(), "period required");
        assert!(Distribution::parse("drift:drift:zipf:1.1:5:5").is_err(), "no nesting");
        assert!(Distribution::parse("drift:zipf:1.1:abc").is_err());
    }

    #[test]
    fn drift_rotates_the_hot_window() {
        // zipf(1.1) front-loads the low rows; after one drift period the
        // hot front must sit a third of the table away.
        let n = 65_536u64;
        let mut g = RequestGen::new(WorkloadSpec {
            total_rows: n,
            distribution: Distribution::Drift {
                inner: Box::new(Distribution::Zipf { theta: 1.1 }),
                period: 50,
            },
            request_rows: (8, 8),
            seed: 11,
        });
        let third = n.div_ceil(3);
        let front_frac = |g: &mut RequestGen, reqs: usize, lo: u64| {
            let mut hits = 0usize;
            let mut total = 0usize;
            for _ in 0..reqs {
                for r in g.next_request() {
                    assert!(r < n);
                    total += 1;
                    // Within a third-of-table band starting at `lo`?
                    if (r + n - lo) % n < third {
                        hits += 1;
                    }
                }
            }
            hits as f64 / total as f64
        };
        // Period 1 (requests 0..50): hot band starts at row 0.
        assert_eq!(g.drift_offset(), 0);
        let p0 = front_frac(&mut g, 50, 0);
        // Period 2 (requests 50..100): hot band starts a third in.
        assert_eq!(g.drift_offset(), third);
        let p1_old_band = front_frac(&mut g, 50, 0);
        let mut g2 = RequestGen::new(WorkloadSpec {
            total_rows: n,
            distribution: Distribution::Drift {
                inner: Box::new(Distribution::Zipf { theta: 1.1 }),
                period: 50,
            },
            request_rows: (8, 8),
            seed: 11,
        });
        for _ in 0..50 {
            g2.next_request();
        }
        let p1_new_band = front_frac(&mut g2, 50, third);
        assert!(p0 > 0.85, "initial hot band too weak: {p0}");
        assert!(p1_new_band > 0.85, "rotated hot band too weak: {p1_new_band}");
        assert!(
            p1_old_band < 0.35,
            "old band still hot after rotation: {p1_old_band}"
        );
    }

    #[test]
    fn drifted_uniform_stays_uniform() {
        let n = 10_000u64;
        let mut g = RequestGen::new(WorkloadSpec {
            total_rows: n,
            distribution: Distribution::Drift {
                inner: Box::new(Distribution::Uniform),
                period: 7,
            },
            request_rows: (16, 16),
            seed: 3,
        });
        let mut front = 0usize;
        let mut total = 0usize;
        for _ in 0..200 {
            for r in g.next_request() {
                assert!(r < n);
                total += 1;
                if r < n / 2 {
                    front += 1;
                }
            }
        }
        let frac = front as f64 / total as f64;
        assert!((frac - 0.5).abs() < 0.05, "drifted uniform skewed: {frac}");
    }

    #[test]
    fn deterministic_per_seed() {
        let mut a = RequestGen::new(WorkloadSpec::uniform(500, 8, 9));
        let mut b = RequestGen::new(WorkloadSpec::uniform(500, 8, 9));
        for _ in 0..10 {
            assert_eq!(a.next_request(), b.next_request());
        }
    }
}

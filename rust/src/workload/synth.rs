//! Synthetic request generators.

use crate::util::rng::Rng;

/// Index distribution over the table's rows.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Distribution {
    /// The paper's benchmark: uniform random rows.
    Uniform,
    /// Zipf-skewed rows (hot embedding rows), scattered over the table.
    Zipf { theta: f64 },
    /// Sequential scan (control: TLB-friendly).
    Sequential,
}

/// Shape of the request stream.
#[derive(Debug, Clone)]
pub struct WorkloadSpec {
    pub total_rows: u64,
    pub distribution: Distribution,
    /// Rows per request (min..=max, drawn uniformly).
    pub request_rows: (usize, usize),
    pub seed: u64,
}

impl WorkloadSpec {
    pub fn uniform(total_rows: u64, request_rows: usize, seed: u64) -> Self {
        Self {
            total_rows,
            distribution: Distribution::Uniform,
            request_rows: (request_rows, request_rows),
            seed,
        }
    }
}

/// Stateful generator producing one request (a row-index batch) at a time.
#[derive(Debug, Clone)]
pub struct RequestGen {
    spec: WorkloadSpec,
    rng: Rng,
    cursor: u64,
}

impl RequestGen {
    pub fn new(spec: WorkloadSpec) -> Self {
        assert!(spec.total_rows > 0);
        assert!(spec.request_rows.0 >= 1 && spec.request_rows.0 <= spec.request_rows.1);
        let rng = Rng::seed_from_u64(spec.seed);
        Self {
            spec,
            rng,
            cursor: 0,
        }
    }

    pub fn next_request(&mut self) -> Vec<u64> {
        let (lo, hi) = self.spec.request_rows;
        let len = if lo == hi {
            lo
        } else {
            lo + self.rng.gen_index(hi - lo + 1)
        };
        (0..len).map(|_| self.next_row()).collect()
    }

    fn next_row(&mut self) -> u64 {
        let n = self.spec.total_rows;
        match self.spec.distribution {
            Distribution::Uniform => self.rng.gen_range(n),
            Distribution::Sequential => {
                let r = self.cursor % n;
                self.cursor += 1;
                r
            }
            Distribution::Zipf { theta } => {
                // Inverse-power approximation (matches sim::access's
                // sampler closely enough for load shaping): draw u in
                // (0,1], rank ~ n * u^(1/(1-theta)), then scatter.
                let u = self.rng.gen_f64().max(1e-12);
                let alpha = 1.0 / (1.0 - theta);
                let rank = ((n as f64) * u.powf(alpha)) as u64;
                rank.min(n - 1).wrapping_mul(0x9E37_79B9_7F4A_7C15) % n
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn uniform_in_range_and_fixed_len() {
        let mut g = RequestGen::new(WorkloadSpec::uniform(1000, 64, 1));
        for _ in 0..50 {
            let req = g.next_request();
            assert_eq!(req.len(), 64);
            assert!(req.iter().all(|&r| r < 1000));
        }
    }

    #[test]
    fn variable_request_sizes() {
        let mut g = RequestGen::new(WorkloadSpec {
            total_rows: 100,
            distribution: Distribution::Uniform,
            request_rows: (1, 10),
            seed: 2,
        });
        let sizes: Vec<usize> = (0..200).map(|_| g.next_request().len()).collect();
        assert!(sizes.iter().all(|&s| (1..=10).contains(&s)));
        assert!(sizes.iter().collect::<std::collections::HashSet<_>>().len() > 3);
    }

    #[test]
    fn sequential_wraps() {
        let mut g = RequestGen::new(WorkloadSpec {
            total_rows: 5,
            distribution: Distribution::Sequential,
            request_rows: (7, 7),
            seed: 0,
        });
        assert_eq!(g.next_request(), vec![0, 1, 2, 3, 4, 0, 1]);
    }

    #[test]
    fn zipf_is_skewed() {
        let mut g = RequestGen::new(WorkloadSpec {
            total_rows: 10_000,
            distribution: Distribution::Zipf { theta: 0.99 },
            request_rows: (1, 1),
            seed: 3,
        });
        let mut counts = std::collections::HashMap::new();
        for _ in 0..20_000 {
            *counts.entry(g.next_request()[0]).or_insert(0u32) += 1;
        }
        let max = *counts.values().max().unwrap();
        assert!(max > 200, "hottest row only {max} hits");
        assert!(counts.len() < 9_000);
    }

    #[test]
    fn deterministic_per_seed() {
        let mut a = RequestGen::new(WorkloadSpec::uniform(500, 8, 9));
        let mut b = RequestGen::new(WorkloadSpec::uniform(500, 8, 9));
        for _ in 0..10 {
            assert_eq!(a.next_request(), b.next_request());
        }
    }
}

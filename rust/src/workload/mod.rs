//! Workload generation for the serving benches and examples: batches of
//! lookup requests over a huge table, with the distributions the paper's
//! use case implies (uniform random cache-line access) plus skewed and
//! trace-replay variants for the ablation studies.

pub mod chaos;
pub mod openloop;
pub mod synth;
pub mod trace;

pub use chaos::{drive_chaos, ChaosConfig, ChaosReport, ChaosTarget};
pub use openloop::{drive, LoadPoint, LoadTarget, OpenLoopConfig};
pub use synth::{RequestGen, WorkloadSpec};
pub use trace::Trace;

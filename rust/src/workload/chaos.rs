//! Verifying chaos soak driver: the closed-loop counterpart to
//! [`openloop`](super::openloop) that checks every returned row against
//! the table's ground truth instead of discarding results.
//!
//! The open-loop driver measures *latency* under load; this driver
//! measures *correctness* under faults.  It drives a target through a
//! seeded fault schedule ([`crate::sim::FaultPlan`]) and asserts the
//! resilience machinery's core contract: no lost or corrupted rows.
//! Concretely, for every request it checks
//!
//! - `Full` outcomes element-wise against [`Table::expected`] — a hedged
//!   duplicate that double-wrote, a retry that scattered into the wrong
//!   slot, or a migration racing a redispatch all show up as a corrupted
//!   row here;
//! - `Partial` outcomes for mask consistency: the validity mask must be
//!   exactly request-length, valid rows must verify, and invalid rows
//!   must be zero-filled (never stale or half-written data);
//! - `Err` outcomes only for bounded resolution time — a failure that is
//!   slow to *fail* is an availability bug even when no data is wrong.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use crate::coordinator::table::Table;
use crate::service::{FleetService, Outcome, Service};
use crate::workload::synth::{Distribution, RequestGen, WorkloadSpec};

/// Anything the chaos driver can aim at: submit one request and block
/// until it resolves to a full result, a partial result, or an error.
pub trait ChaosTarget: Sync {
    fn run_outcome(
        &self,
        rows: Arc<Vec<u64>>,
        deadline: Option<Duration>,
    ) -> anyhow::Result<Outcome>;
}

impl ChaosTarget for Service {
    fn run_outcome(
        &self,
        rows: Arc<Vec<u64>>,
        deadline: Option<Duration>,
    ) -> anyhow::Result<Outcome> {
        self.submit(rows, deadline)?.wait_outcome()
    }
}

impl ChaosTarget for FleetService {
    fn run_outcome(
        &self,
        rows: Arc<Vec<u64>>,
        deadline: Option<Duration>,
    ) -> anyhow::Result<Outcome> {
        self.submit(rows, deadline)?.wait_outcome()
    }
}

/// Remote counterpart: the soak drives the full network path — framing,
/// admission, response encoding — and still verifies every returned row
/// against the table.  With a [`crate::net::NetFaultPlan`] on the pool,
/// injected transport faults (torn frames, half-closes, drops) surface
/// here as `Err` outcomes, never as corrupted rows.
impl ChaosTarget for crate::net::RemotePool {
    fn run_outcome(
        &self,
        rows: Arc<Vec<u64>>,
        deadline: Option<Duration>,
    ) -> anyhow::Result<Outcome> {
        self.request(&rows, deadline)
    }
}

/// Chaos soak configuration.
#[derive(Debug, Clone)]
pub struct ChaosConfig {
    /// Total requests to drive (closed loop: the soak is request-bounded,
    /// not wall-clock-bounded, so CI runs are deterministic in size).
    pub requests: usize,
    /// Rows per request, drawn uniformly from this inclusive range.
    pub request_rows: (usize, usize),
    /// Row-id distribution (the acceptance soak uses `drift:zipf` so hot
    /// windows move while faults fire).
    pub distribution: Distribution,
    /// Seeds both the request generator and nothing else — fault
    /// schedules carry their own seed in the [`crate::sim::FaultPlan`].
    pub seed: u64,
    /// Deadline attached to every request (None = unbounded).
    pub deadline: Option<Duration>,
    /// Concurrent client threads (closed loop: each thread submits its
    /// next request only after the previous one resolved).
    pub concurrency: usize,
}

impl Default for ChaosConfig {
    fn default() -> Self {
        Self {
            requests: 200,
            request_rows: (16, 96),
            distribution: Distribution::Drift {
                inner: Box::new(Distribution::Zipf { theta: 1.1 }),
                period: 400,
            },
            seed: 7,
            deadline: Some(Duration::from_millis(50)),
            concurrency: 4,
        }
    }
}

/// What the soak observed.  `corrupted_rows` and `mask_violations` are
/// the hard-failure counters: any nonzero value means the resilience
/// layer returned wrong data, which no amount of injected faultiness
/// excuses.
#[derive(Debug, Clone, Default)]
pub struct ChaosReport {
    /// Requests that resolved `Full`.
    pub completed: u64,
    /// Requests that resolved `Partial`.
    pub partials: u64,
    /// Requests that resolved `Err`.
    pub failed: u64,
    /// Rows checked against the table and found exact.
    pub valid_rows_checked: u64,
    /// Rows a `Partial` mask declared missing (zero-filled, not checked).
    pub invalid_rows: u64,
    /// Rows that failed verification: a delivered row whose payload does
    /// not match the table, or a masked-out row that was not zero-filled.
    pub corrupted_rows: u64,
    /// `Partial` outcomes whose mask length did not equal the request
    /// length.
    pub mask_violations: u64,
    /// p99 resolution latency of successful (`Full` or `Partial`)
    /// requests, microseconds.
    pub p99_us: u64,
    /// p99 resolution latency of failed requests, microseconds — failures
    /// must be *fast*; a request that burns its whole retry budget before
    /// erroring still has to resolve in bounded time.
    pub failure_p99_us: u64,
}

impl ChaosReport {
    /// Fraction of requests that returned at least some verified data.
    pub fn goodput(&self) -> f64 {
        let total = self.completed + self.partials + self.failed;
        if total == 0 {
            return 0.0;
        }
        (self.completed + self.partials) as f64 / total as f64
    }

    /// Panic if the soak observed any lost or corrupted rows.  Split out
    /// from the driver so callers can inspect the report before dying.
    pub fn assert_no_corruption(&self) {
        assert_eq!(
            self.corrupted_rows, 0,
            "chaos soak delivered corrupted rows: {self:?}"
        );
        assert_eq!(
            self.mask_violations, 0,
            "chaos soak delivered malformed partial masks: {self:?}"
        );
    }
}

#[derive(Default)]
struct LocalTally {
    completed: u64,
    partials: u64,
    failed: u64,
    valid_rows_checked: u64,
    invalid_rows: u64,
    corrupted_rows: u64,
    mask_violations: u64,
    latency: Vec<Duration>,
    failure_latency: Vec<Duration>,
}

/// Verify one delivered row against the table.  A row is exact or it is
/// corrupted — float equality is intentional: the pipeline moves bytes,
/// it does not do arithmetic on them.
fn row_exact(out: &[f32], k: usize, row: u64, table: &Table) -> bool {
    let d = table.d;
    (0..d).all(|j| out[k * d + j] == table.expected(row, j))
}

fn verify_full(out: &[f32], rows: &[u64], table: &Table, tally: &mut LocalTally) {
    for (k, &row) in rows.iter().enumerate() {
        if row_exact(out, k, row, table) {
            tally.valid_rows_checked += 1;
        } else {
            tally.corrupted_rows += 1;
        }
    }
}

fn verify_partial(
    out: &[f32],
    valid: &[bool],
    rows: &[u64],
    table: &Table,
    tally: &mut LocalTally,
) {
    if valid.len() != rows.len() {
        tally.mask_violations += 1;
        return;
    }
    let d = table.d;
    for (k, &row) in rows.iter().enumerate() {
        if valid[k] {
            if row_exact(out, k, row, table) {
                tally.valid_rows_checked += 1;
            } else {
                tally.corrupted_rows += 1;
            }
        } else {
            // Masked-out rows must be zero-filled: stale slab contents
            // leaking through the mask is a correctness bug.
            if out[k * d..(k + 1) * d].iter().all(|&v| v == 0.0) {
                tally.invalid_rows += 1;
            } else {
                tally.corrupted_rows += 1;
            }
        }
    }
}

fn p99_us(mut lat: Vec<Duration>) -> u64 {
    if lat.is_empty() {
        return 0;
    }
    lat.sort_unstable();
    let idx = ((lat.len() - 1) as f64 * 0.99) as usize;
    lat[idx].as_micros() as u64
}

/// Drive `cfg.requests` verified requests at the target and tally what
/// came back.  Request payloads are pre-drawn single-threaded from the
/// seeded generator, so the offered row stream is identical across runs
/// regardless of `concurrency` — only interleaving varies.
pub fn drive_chaos<T: ChaosTarget + ?Sized>(
    target: &T,
    table: &Table,
    cfg: &ChaosConfig,
) -> ChaosReport {
    let mut gen = RequestGen::new(WorkloadSpec {
        total_rows: table.rows,
        distribution: cfg.distribution.clone(),
        request_rows: cfg.request_rows,
        seed: cfg.seed,
    });
    let requests: Vec<Arc<Vec<u64>>> = (0..cfg.requests)
        .map(|_| Arc::new(gen.next_request()))
        .collect();

    let next = AtomicUsize::new(0);
    let tallies: Mutex<Vec<LocalTally>> = Mutex::new(Vec::new());

    std::thread::scope(|s| {
        for _ in 0..cfg.concurrency.max(1) {
            s.spawn(|| {
                let mut tally = LocalTally::default();
                loop {
                    let i = next.fetch_add(1, Ordering::Relaxed);
                    let Some(rows) = requests.get(i) else { break };
                    let t0 = Instant::now();
                    match target.run_outcome(Arc::clone(rows), cfg.deadline) {
                        Ok(Outcome::Full(out)) => {
                            tally.latency.push(t0.elapsed());
                            tally.completed += 1;
                            verify_full(&out, rows, table, &mut tally);
                        }
                        Ok(Outcome::Partial { rows: out, valid }) => {
                            tally.latency.push(t0.elapsed());
                            tally.partials += 1;
                            verify_partial(&out, &valid, rows, table, &mut tally);
                        }
                        Err(_) => {
                            tally.failure_latency.push(t0.elapsed());
                            tally.failed += 1;
                        }
                    }
                }
                tallies.lock().unwrap().push(tally);
            });
        }
    });

    let mut report = ChaosReport::default();
    let mut latency = Vec::new();
    let mut failure_latency = Vec::new();
    for t in tallies.into_inner().unwrap() {
        report.completed += t.completed;
        report.partials += t.partials;
        report.failed += t.failed;
        report.valid_rows_checked += t.valid_rows_checked;
        report.invalid_rows += t.invalid_rows;
        report.corrupted_rows += t.corrupted_rows;
        report.mask_violations += t.mask_violations;
        latency.extend(t.latency);
        failure_latency.extend(t.failure_latency);
    }
    report.p99_us = p99_us(latency);
    report.failure_p99_us = p99_us(failure_latency);
    report
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn verify_full_flags_corruption() {
        let table = Table::synthetic(64, 4);
        let rows = vec![3u64, 7, 11];
        let mut out = Vec::new();
        for &r in &rows {
            for j in 0..4 {
                out.push(table.expected(r, j));
            }
        }
        let mut tally = LocalTally::default();
        verify_full(&out, &rows, &table, &mut tally);
        assert_eq!(tally.valid_rows_checked, 3);
        assert_eq!(tally.corrupted_rows, 0);

        out[5] += 1.0; // corrupt one element of row index 1
        let mut tally = LocalTally::default();
        verify_full(&out, &rows, &table, &mut tally);
        assert_eq!(tally.valid_rows_checked, 2);
        assert_eq!(tally.corrupted_rows, 1);
    }

    #[test]
    fn verify_partial_checks_mask_and_zero_fill() {
        let table = Table::synthetic(64, 2);
        let rows = vec![5u64, 9];
        let mut out = vec![0.0f32; 4];
        out[0] = table.expected(5, 0);
        out[1] = table.expected(5, 1);

        let mut tally = LocalTally::default();
        verify_partial(&out, &[true, false], &rows, &table, &mut tally);
        assert_eq!(tally.valid_rows_checked, 1);
        assert_eq!(tally.invalid_rows, 1);
        assert_eq!(tally.corrupted_rows, 0);
        assert_eq!(tally.mask_violations, 0);

        // Stale data leaking through a masked-out slot is corruption.
        out[3] = 42.0;
        let mut tally = LocalTally::default();
        verify_partial(&out, &[true, false], &rows, &table, &mut tally);
        assert_eq!(tally.corrupted_rows, 1);

        // Wrong-length mask is a violation, rows are not inspected.
        let mut tally = LocalTally::default();
        verify_partial(&out, &[true], &rows, &table, &mut tally);
        assert_eq!(tally.mask_violations, 1);
        assert_eq!(tally.valid_rows_checked, 0);
    }

    #[test]
    fn report_goodput_and_p99() {
        let report = ChaosReport {
            completed: 6,
            partials: 2,
            failed: 2,
            ..Default::default()
        };
        assert!((report.goodput() - 0.8).abs() < 1e-9);
        report.assert_no_corruption();

        let lat: Vec<Duration> = (1..=100).map(Duration::from_micros).collect();
        assert_eq!(p99_us(lat), 99);
        assert_eq!(p99_us(Vec::new()), 0);
    }
}

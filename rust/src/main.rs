//! `a100win` CLI: probe the (simulated) card, regenerate the paper's
//! figures, and serve lookups through the async ticketed `service` facade
//! with TLB-aware placement.

use std::path::PathBuf;
use std::sync::Arc;
use std::time::{Duration, Instant};

use a100win::config::MachineConfig;
use a100win::coordinator::{
    AdaptiveConfig, CardSpec, ControlPlaneConfig, Decision, EmbeddingServer, Lever,
    PlacementPolicy, RemapConfig, ReplicateConfig, ServerConfig, SplitterConfig, Table,
    WindowPlan,
};
use a100win::coordinator::GroupHealth;
use a100win::experiments::{self, Effort};
use a100win::net::{ClientConfig, NetClient, NetConfig, NetFaultPlan, NetServer, RemotePool, Target};
use a100win::probe::{ProbeConfig, Prober, TopologyMap};
use a100win::runtime::Runtime;
use a100win::service::{
    FleetConfig, FleetService, GlobalAdmission, Outcome, OverloadPolicy, ResilienceConfig,
    Service, SessionConfig, SimBackend, SimBackendConfig, SimTiming,
};
use a100win::sim::{FaultPlan, Machine, StallKind};
use a100win::util::json::Json;
use a100win::workload::{
    drive, drive_chaos, synth::Distribution, ChaosConfig, ChaosReport, OpenLoopConfig, RequestGen,
    WorkloadSpec,
};

const USAGE: &str = "\
a100win — full-speed random access to the entire (simulated) A100 memory

USAGE:
    a100win probe   [--seed N] [--out FILE] [--effort quick|full]
    a100win fig     <1..6|0|all> [--seed N] [--effort quick|full]
    a100win serve   [--backend sim|pjrt] [--policy naive|sm-to-chunk|group-to-chunk]
                    [--windows N] [--requests N] [--rows-per-request N]
                    [--cards N] [--rows-per-window N] [--artifacts DIR]
    a100win bench-serve [--backend sim] [--policy P] [--placer static|deal-only|adaptive]
                    [--windows N] [--rows-per-request N] [--duration-ms N]
                    [--rps A,B,C...] [--requests N] [--skew uniform|zipf:T|zipf-scattered:T]
                    [--skew-drift drift:SKEW:PERIOD] [--cards N] [--sim-timescale F]
                    [--remap] [--replicate] [--verify N]
                    [--chaos [--seed N] [--deadline-ms N]]  (chaos soak, see below)
                    [--remote [--conns N]]  (drive over loopback TCP, see below)
    a100win serve-net [--port N] [--http-port N] [--cards N] [--windows N]
                    [--rows-per-window N] [--max-conns N] [--global-slots N]
                    [--sim-timescale F] [--selfcheck N] [--duration-ms N]
                    [--drain-ms N]
    a100win explain [--seed N]
    a100win remote  [--peers N] [--region-gib N]
    a100win analytic [--region-gib N]
    a100win help

SUBCOMMANDS:
    probe    run the paper's probing pipeline (Figs 2-5) on the simulated
             card and write the TopologyMap artifact
    fig      regenerate a paper figure's data series (0 = txn-size aside)
    serve    serve ticketed lookups through service::Service.
             --backend sim (default): hermetic, no artifacts — gathers on
             the host, device cost from the DES; verifies every row.
             --backend pjrt: AOT artifacts via PJRT (requires `make
             artifacts`).  --cards N>1 (sim only): shard the table across
             N probed cards via a FleetPlan and merge in request order.
    bench-serve
             open-loop Poisson QPS sweep against the sim-backed facade:
             offered vs achieved rps, latency percentiles (EXPERIMENTS.md
             §Serve).  --skew zipf:<theta> front-loads traffic onto low
             windows; --skew-drift drift:zipf:1.1:2000 rotates the hotspot
             every 2000 requests; --placer deal-only re-deals groups from
             observed load, --placer adaptive additionally re-splits window
             boundaries (the two-level control plane, EXPERIMENTS.md
             §Repartition); --cards N>1 runs the sweep against a fleet
             whose control plane may also migrate rows across cards
             (zero-copy); --sim-timescale paces completions by simulated
             device time so the wall-clock knee is policy-dependent;
             --remap arms the fourth lever, TLB-aware hot-row repacking:
             learned hot rows are copied into page-aligned window prefixes
             and published live like a re-split (implies adaptive
             epoching, and with --cards > 1 rides each card's own control
             plane under the fleet's epoch driver);
             --replicate (needs --cards > 1) arms the fifth lever:
             a shard hotter than its owner card gets zero-copy read
             replicas on other cards, routed by power-of-two-choices over
             live queue depth, dropped again when the hotspot subsides;
             --verify N is the CI regression guard: after the
             sweep it serves N fully-verified requests (every merged row
             checked against the table), asserts the repartition counters
             are consistent (generations == redeals + resplits +
             migrations + repacks + replications), and audits the
             published remap plan's permutation invariants.
             --chaos replaces the QPS sweep with a verifying chaos soak:
             a seeded fault schedule (worker stalls, group outages,
             flapping health — sim/fault.rs) fires against the fully
             armed resilience stack (retries, hedging, partial results,
             circuit breakers) under drift:zipf load; every delivered
             row is checked against the table and the run fails on any
             corrupted row, malformed partial mask, total outage, or
             unbounded failure-resolution p99.  --seed picks the fault
             schedule, --deadline-ms the per-request deadline, --verify
             N re-checks N requests after the soak settles.
             --remote runs the sweep (or, with --chaos, the soak) through
             the network front door: an in-process serve-net server on
             loopback TCP driven by a pooled binary-protocol client
             (--conns N connections).  The remote chaos soak additionally
             injects deterministic *transport* faults client-side (torn
             frames, half-closes, connection drops) and finishes with a
             graceful-drain demonstration: in-flight requests complete
             while a new connection is refused with an explicit shed
             response.
    serve-net
             serve the binary wire protocol on --port (0 = ephemeral) and
             the HTTP health/lookup channel on --http-port.  Overload is
             shed explicitly (Shed frames / HTTP 429+503), slow-loris
             clients are disconnected, and shutdown is a graceful drain:
             stop accepting, finish in-flight tickets, release slabs.
             --selfcheck N verifies N requests end-to-end over loopback
             (plus /healthz, /readyz, and a JSON lookup) and exits via
             drain; otherwise the server runs for --duration-ms then
             drains (--drain-ms bounds the wait).
    explain  print machine config, ground-truth topology, and what the
             paper's technique does on this card
    remote   NVLink ingress experiment: the paper's OTHER 64GB TLB (§1.2)
    analytic closed-form throughput predictions (no simulation)
";

struct Args {
    positional: Vec<String>,
    flags: std::collections::HashMap<String, String>,
}

impl Args {
    fn parse(argv: &[String]) -> anyhow::Result<Self> {
        let mut positional = Vec::new();
        let mut flags = std::collections::HashMap::new();
        let mut i = 0;
        while i < argv.len() {
            let a = &argv[i];
            if let Some(name) = a.strip_prefix("--") {
                // A flag followed by another flag (or nothing) is boolean
                // (`--chaos`); otherwise it consumes the next token.
                match argv.get(i + 1) {
                    Some(v) if !v.starts_with("--") => {
                        flags.insert(name.to_string(), v.clone());
                        i += 2;
                    }
                    _ => {
                        flags.insert(name.to_string(), String::new());
                        i += 1;
                    }
                }
            } else {
                positional.push(a.clone());
                i += 1;
            }
        }
        Ok(Self { positional, flags })
    }

    /// Reject any flag the subcommand does not define.  A typo'd flag
    /// must be an error, not a silent no-op: `--choas` quietly running
    /// the *benchmark* instead of the chaos soak is how a CI gate rots.
    fn reject_unknown(&self, cmd: &str, allowed: &[&str]) -> anyhow::Result<()> {
        let mut unknown: Vec<&str> = self
            .flags
            .keys()
            .map(String::as_str)
            .filter(|k| !allowed.contains(k))
            .collect();
        unknown.sort_unstable();
        if let Some(first) = unknown.first() {
            anyhow::bail!("unknown flag --{first} for '{cmd}' (see `a100win help`)");
        }
        Ok(())
    }

    fn flag(&self, name: &str) -> Option<&str> {
        self.flags.get(name).map(String::as_str)
    }

    fn bool_flag(&self, name: &str) -> bool {
        self.flags.contains_key(name)
    }

    fn u64_flag(&self, name: &str, default: u64) -> anyhow::Result<u64> {
        match self.flag(name) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| anyhow::anyhow!("--{name} expects a number, got '{v}'")),
        }
    }

    fn f64_flag(&self, name: &str, default: f64) -> anyhow::Result<f64> {
        match self.flag(name) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| anyhow::anyhow!("--{name} expects a number, got '{v}'")),
        }
    }

    fn effort(&self) -> anyhow::Result<Effort> {
        match self.flag("effort") {
            None => Ok(Effort::from_env()),
            Some("quick") => Ok(Effort::Quick),
            Some("full") => Ok(Effort::Full),
            Some(v) => anyhow::bail!("--effort quick|full, got '{v}'"),
        }
    }
}

fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    if let Err(e) = run(&argv) {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn run(argv: &[String]) -> anyhow::Result<()> {
    let Some(cmd) = argv.first().map(String::as_str) else {
        print!("{USAGE}");
        return Ok(());
    };
    let args = Args::parse(&argv[1..])?;
    args.reject_unknown(cmd, allowed_flags(cmd))?;
    match cmd {
        "probe" => cmd_probe(&args),
        "fig" => cmd_fig(&args),
        "serve" => cmd_serve(&args),
        "bench-serve" => cmd_bench_serve(&args),
        "serve-net" => cmd_serve_net(&args),
        "explain" => cmd_explain(&args),
        "remote" => cmd_remote(&args),
        "analytic" => cmd_analytic(&args),
        "help" | "--help" | "-h" => {
            print!("{USAGE}");
            Ok(())
        }
        other => {
            anyhow::bail!("unknown subcommand '{other}' (try `a100win help`)")
        }
    }
}

/// The full flag vocabulary per subcommand ([`Args::reject_unknown`]).
fn allowed_flags(cmd: &str) -> &'static [&'static str] {
    match cmd {
        "probe" => &["seed", "out", "effort"],
        "fig" => &["seed", "effort"],
        "serve" => &[
            "backend",
            "policy",
            "windows",
            "requests",
            "rows-per-request",
            "cards",
            "rows-per-window",
            "artifacts",
        ],
        "bench-serve" => &[
            "backend",
            "policy",
            "placer",
            "windows",
            "rows-per-request",
            "duration-ms",
            "rps",
            "requests",
            "skew",
            "skew-drift",
            "cards",
            "sim-timescale",
            "remap",
            "replicate",
            "verify",
            "chaos",
            "seed",
            "deadline-ms",
            "remote",
            "conns",
        ],
        "serve-net" => &[
            "port",
            "http-port",
            "cards",
            "windows",
            "rows-per-window",
            "max-conns",
            "global-slots",
            "sim-timescale",
            "selfcheck",
            "duration-ms",
            "drain-ms",
        ],
        "explain" => &["seed"],
        "remote" => &["peers", "region-gib"],
        "analytic" => &["region-gib"],
        _ => &[],
    }
}

fn machine_with_seed(seed: u64) -> anyhow::Result<Machine> {
    let mut cfg = MachineConfig::a100_80gb();
    cfg.topology.smid_permutation_seed = seed;
    Machine::new(cfg).map_err(|e| anyhow::anyhow!(e))
}

fn cmd_probe(args: &Args) -> anyhow::Result<()> {
    let seed = args.u64_flag("seed", 0xA100)?;
    let effort = args.effort()?;
    let machine = machine_with_seed(seed)?;
    let mut cfg = ProbeConfig::for_machine(&machine);
    if effort == Effort::Quick {
        cfg.pair.accesses_per_sm = 1_500;
        cfg.verify.accesses_per_sm = 3_000;
    }
    eprintln!(
        "probing simulated card (seed {seed:#x}): {} SM pairs + verification...",
        machine.topology().sm_count() * (machine.topology().sm_count() + 1) / 2
    );
    let t = std::time::Instant::now();
    let outcome = Prober::with_config(&machine, cfg).run()?;
    eprintln!("probe finished in {:.1}s", t.elapsed().as_secs_f64());

    println!(
        "discovered {} resource groups (sizes {:?})",
        outcome.map.groups.len(),
        outcome
            .map
            .groups
            .iter()
            .map(|g| g.len())
            .collect::<Vec<_>>()
    );
    println!(
        "per-group TLB reach estimate: {:.1} GiB",
        outcome.map.reach_bytes as f64 / (1u64 << 30) as f64
    );
    println!(
        "groups independent (Fig-5 check): {}",
        outcome.map.independent
    );
    println!("reach sweep (GiB -> GB/s):");
    for (bytes, gbps) in &outcome.reach_curve {
        println!(
            "  {:6.1} -> {gbps:7.1}",
            *bytes as f64 / (1u64 << 30) as f64
        );
    }
    let out = PathBuf::from(args.flag("out").unwrap_or("topomap.json"));
    outcome.map.save(&out)?;
    println!("wrote {}", out.display());
    Ok(())
}

fn cmd_fig(args: &Args) -> anyhow::Result<()> {
    let which = args
        .positional
        .first()
        .ok_or_else(|| anyhow::anyhow!("fig needs a figure number or 'all'"))?;
    let seed = args.u64_flag("seed", 42)?;
    let effort = args.effort()?;
    if which == "all" {
        experiments::run_all(effort, seed)
    } else {
        let n: u32 = which
            .parse()
            .map_err(|_| anyhow::anyhow!("figure must be 0-6 or 'all'"))?;
        experiments::run_figure(n, effort, seed)
    }
}

fn cmd_serve(args: &Args) -> anyhow::Result<()> {
    match args.flag("backend").unwrap_or("sim") {
        "sim" => {
            if args.u64_flag("cards", 1)? > 1 {
                serve_fleet_sim(args)
            } else {
                serve_sim(args)
            }
        }
        "pjrt" => serve_pjrt(args),
        other => anyhow::bail!("--backend sim|pjrt, got '{other}'"),
    }
}

/// Row width of the synthetic serving table: d=32 f32s = one 128 B line.
const SERVE_D: usize = 32;

/// A pending response: redeem once to get the gathered rows.
type WaitFn = Box<dyn FnOnce() -> anyhow::Result<Vec<f32>>>;

/// Drain-and-verify loop shared by the serve paths: pipelined ticketed
/// submission (a window of in-flight tickets, never one-at-a-time
/// blocking), every returned row checked against `Table::expected`.
fn serve_requests(
    submit: impl Fn(Arc<Vec<u64>>) -> anyhow::Result<WaitFn>,
    table: &Table,
    requests: u64,
    rows_per_request: usize,
) -> anyhow::Result<u64> {
    let d = table.d;
    let mut gen = RequestGen::new(WorkloadSpec::uniform(table.rows, rows_per_request, 7));
    let depth = 32usize;
    let mut inflight: std::collections::VecDeque<(Arc<Vec<u64>>, WaitFn)> = Default::default();
    let mut verified = 0u64;
    let mut drain_one =
        |inflight: &mut std::collections::VecDeque<(Arc<Vec<u64>>, WaitFn)>| -> anyhow::Result<()> {
            let (rows, wait) = inflight.pop_front().expect("non-empty");
            let out = wait()?;
            anyhow::ensure!(out.len() == rows.len() * d, "short response");
            for (k, &row) in rows.iter().enumerate() {
                for j in 0..d {
                    anyhow::ensure!(
                        out[k * d + j] == table.expected(row, j),
                        "row {row} column {j}: got {} want {}",
                        out[k * d + j],
                        table.expected(row, j)
                    );
                }
                verified += 1;
            }
            Ok(())
        };
    for _ in 0..requests {
        let rows = Arc::new(gen.next_request());
        let wait = submit(Arc::clone(&rows))?;
        inflight.push_back((rows, wait));
        if inflight.len() >= depth {
            drain_one(&mut inflight)?;
        }
    }
    while !inflight.is_empty() {
        drain_one(&mut inflight)?;
    }
    Ok(verified)
}

fn serve_sim(args: &Args) -> anyhow::Result<()> {
    let policy = PlacementPolicy::parse(args.flag("policy").unwrap_or("group-to-chunk"))?;
    let windows = args.u64_flag("windows", 2)? as usize;
    let requests = args.u64_flag("requests", 200)?;
    let rows_per_request = args.u64_flag("rows-per-request", 512)? as usize;
    let rows_per_window = args.u64_flag("rows-per-window", 32_768)?;

    let machine = machine_with_seed(0xA100)?;
    // Serve against the ground-truth map (a real deployment would load
    // `a100win probe`'s output; identical content here).
    let map = TopologyMap::ground_truth(&machine);
    let rows = rows_per_window * windows as u64;
    let table = Table::synthetic(rows, SERVE_D);
    let plan = WindowPlan::split(rows, (SERVE_D * 4) as u64, windows);
    println!(
        "table: {rows} rows x {SERVE_D} f32 ({} MiB), {windows} windows, policy {policy}, sim backend",
        rows * (SERVE_D as u64) * 4 / (1 << 20),
    );

    let backend = Arc::new(SimBackend::start(
        SimBackendConfig::new(policy),
        &map,
        plan,
        table.view(),
        SimTiming::machine(machine),
    )?);
    let service = Service::new(backend.clone());
    // All CLI traffic flows through one admission-controlled session: the
    // in-flight budget backpressures (Queue) instead of shedding.  The
    // session also draws on a (here single-tenant) weighted global budget,
    // the multi-tenant front door a fleet deployment shares.
    let global = GlobalAdmission::new(128);
    let session = service.session_with_budget(
        "cli",
        SessionConfig {
            max_in_flight: 64,
            overload: OverloadPolicy::Queue,
            deadline: None,
        },
        &global,
        1.0,
    );

    let t = std::time::Instant::now();
    let verified = serve_requests(
        |rows| {
            let ticket = session.submit(rows)?;
            Ok(Box::new(move || ticket.wait()))
        },
        &table,
        requests,
        rows_per_request,
    )?;
    let dt = t.elapsed();

    let m = service.metrics();
    println!(
        "served {requests} requests ({verified} rows, all verified) in {:.2}s",
        dt.as_secs_f64()
    );
    println!(
        "host throughput: {:.0} rows/s ({:.1} MB/s of gathered lines)",
        m.rows as f64 / dt.as_secs_f64(),
        m.rows as f64 * (SERVE_D as f64 * 4.0) / dt.as_secs_f64() / 1e6
    );
    println!("{}", m.report());
    for t in global.report() {
        println!(
            "tenant '{}': weight {:.1}, guaranteed {} global slots, {} in flight",
            t.tenant, t.weight, t.guaranteed, t.used
        );
    }
    println!("simulated device (per group, window-pinned placement):");
    for r in backend.sim_report() {
        println!(
            "  group {:2}: {:8} rows in {:8.2} ms device time -> {:6.1} GB/s",
            r.group, r.rows, r.sim_ms, r.simulated_gbps
        );
    }
    println!(
        "aggregate (makespan over groups): {:.1} GB/s",
        backend.aggregate_sim_gbps()
    );
    service.shutdown();
    Ok(())
}

fn serve_fleet_sim(args: &Args) -> anyhow::Result<()> {
    let cards = args.u64_flag("cards", 2)? as usize;
    let requests = args.u64_flag("requests", 200)?;
    let rows_per_request = args.u64_flag("rows-per-request", 512)? as usize;
    let rows_per_window = args.u64_flag("rows-per-window", 32_768)?;

    // Probe map per card: enumeration seeds differ card to card (paper
    // §1.1), so each shard gets its own TopologyMap + placement.
    let mut specs = Vec::new();
    for i in 0..cards {
        let machine = machine_with_seed(0xA100 + 0x1111 * i as u64)?;
        let spec = CardSpec {
            map: TopologyMap::ground_truth(&machine),
            memory_bytes: machine.config().memory.total_bytes,
        };
        specs.push((spec, SimTiming::machine(machine)));
    }

    let rows = rows_per_window * cards as u64;
    let table = Table::synthetic(rows, SERVE_D);
    println!(
        "fleet: {cards} cards, table {rows} rows x {SERVE_D} f32 ({} MiB), sim backend",
        rows * (SERVE_D as u64) * 4 / (1 << 20),
    );
    let fleet = FleetService::build_sim(specs, &table, Default::default(), 0xF1EE7)?;
    for s in &fleet.plan().shards {
        println!(
            "  card {}: rows [{}, {}) in {} windows",
            s.card,
            s.start_row,
            s.end_row(),
            s.plan.count()
        );
    }

    let t = std::time::Instant::now();
    let verified = serve_requests(
        |rows| {
            let ticket = fleet.submit(rows, None)?;
            Ok(Box::new(move || ticket.wait()))
        },
        &table,
        requests,
        rows_per_request,
    )?;
    let dt = t.elapsed();
    println!(
        "served {requests} requests ({verified} rows, merged in request order, all verified) \
         in {:.2}s",
        dt.as_secs_f64()
    );
    println!("per-card metrics:");
    for (card, m) in fleet.per_card_metrics() {
        println!("  card {card}: {}", m.report());
    }
    fleet.shutdown();
    Ok(())
}

fn serve_pjrt(args: &Args) -> anyhow::Result<()> {
    let policy = PlacementPolicy::parse(args.flag("policy").unwrap_or("group-to-chunk"))?;
    let windows = args.u64_flag("windows", 2)? as usize;
    let requests = args.u64_flag("requests", 200)?;
    let rows_per_request = args.u64_flag("rows-per-request", 512)? as usize;
    let artifacts = match args.flag("artifacts") {
        Some(d) => PathBuf::from(d),
        None => Runtime::default_artifacts_dir()?,
    };

    // Table sized to the artifacts' static shard shape.
    let rt = Runtime::new(&artifacts)?;
    let meta = rt
        .manifest()
        .first_of("lookup")
        .ok_or_else(|| anyhow::anyhow!("no lookup artifacts"))?;
    drop(rt);
    let rows = (meta.n * windows) as u64;
    println!(
        "table: {rows} rows x {} f32 ({} MiB), {windows} windows, policy {policy}, pjrt backend",
        meta.d,
        rows * (meta.d as u64) * 4 / (1 << 20),
    );

    let machine = machine_with_seed(0xA100)?;
    let map = TopologyMap::ground_truth(&machine);
    let table = Table::synthetic(rows, meta.d);
    let plan = WindowPlan::split(rows, 128, windows);
    let mut cfg = ServerConfig::new(artifacts);
    cfg.policy = policy;
    let service = Service::new(Arc::new(EmbeddingServer::start(
        cfg,
        &map,
        plan,
        table.view(),
    )?));

    let t = std::time::Instant::now();
    let verified = serve_requests(
        |rows| {
            let ticket = service.submit(rows, None)?;
            Ok(Box::new(move || ticket.wait()))
        },
        &table,
        requests,
        rows_per_request,
    )?;
    let dt = t.elapsed();
    let m = service.metrics();
    println!(
        "served {requests} requests ({verified} rows, all verified) in {:.2}s",
        dt.as_secs_f64()
    );
    println!(
        "throughput: {:.0} rows/s ({:.1} MB/s of gathered lines)",
        m.rows as f64 / dt.as_secs_f64(),
        m.rows as f64 * (meta.d as f64 * 4.0) / dt.as_secs_f64() / 1e6
    );
    println!("{}", m.report());
    service.shutdown();
    Ok(())
}

/// Open-loop QPS sweep against the sim-backed facade: the standard
/// methodology for memory-system serving benchmarks (EXPERIMENTS.md
/// §Serve).
fn cmd_bench_serve(args: &Args) -> anyhow::Result<()> {
    match args.flag("backend").unwrap_or("sim") {
        "sim" => {}
        other => anyhow::bail!("bench-serve only supports --backend sim, got '{other}'"),
    }
    if args.bool_flag("remote") {
        // The sweep (or soak) through the network front door.
        return cmd_bench_remote(args);
    }
    if args.bool_flag("chaos") {
        return cmd_chaos(args);
    }
    let policy = PlacementPolicy::parse(args.flag("policy").unwrap_or("group-to-chunk"))?;
    let placer_name = args.flag("placer").unwrap_or("static");
    // The repartition ladder: static < deal-only (group re-deal) <
    // adaptive (two-level: re-deal + window re-split).
    let (adaptive, resplit) = match placer_name {
        "static" => (None, None),
        "deal-only" => (
            Some(AdaptiveConfig {
                // Rebalance continuously while the sweep runs.
                epoch: Some(Duration::from_millis(20)),
                ..AdaptiveConfig::default()
            }),
            None,
        ),
        "adaptive" | "two-level" => (
            Some(AdaptiveConfig {
                epoch: Some(Duration::from_millis(20)),
                ..AdaptiveConfig::default()
            }),
            Some(SplitterConfig::default()),
        ),
        other => anyhow::bail!("--placer static|deal-only|adaptive, got '{other}'"),
    };
    // --remap arms the repack lever.  Its hot-set signal rides the same
    // epoch machinery as re-deals, so it implies adaptive epoching even
    // under --placer static.
    let remap = args.bool_flag("remap").then(RemapConfig::default);
    // --replicate arms the fifth lever (fleet scope): hot-shard read
    // replication with power-of-two-choices routing.  The observed-demand
    // gate is disabled (capacity_fraction 0.0) because open-loop
    // wall-clock demand can never meet a *simulated*-bandwidth bar — the
    // hot-share gate alone decides (see `ReplicateConfig`).
    let replicate = args.bool_flag("replicate").then(|| ReplicateConfig {
        capacity_fraction: 0.0,
        ..ReplicateConfig::default()
    });
    // Both levers ride the same epoch machinery as re-deals, so they
    // imply adaptive epoching even under --placer static.
    let adaptive = match (adaptive, remap.is_some() || replicate.is_some()) {
        (None, true) => Some(AdaptiveConfig {
            epoch: Some(Duration::from_millis(20)),
            ..AdaptiveConfig::default()
        }),
        (a, _) => a,
    };
    // --skew-drift takes precedence: the rotating-hotspot stressor the
    // control plane exists for.
    let skew = match args.flag("skew-drift") {
        Some(spec) => Distribution::parse(spec)?,
        None => Distribution::parse(args.flag("skew").unwrap_or("uniform"))?,
    };
    let cards = args.u64_flag("cards", 1)? as usize;
    let windows = args.u64_flag("windows", 2)? as usize;
    let rows_per_request = args.u64_flag("rows-per-request", 256)? as usize;
    let duration = Duration::from_millis(args.u64_flag("duration-ms", 300)?);
    let max_requests = match args.u64_flag("requests", 0)? {
        0 => None,
        n => Some(n),
    };
    let timescale = args.f64_flag("sim-timescale", 0.0)?;
    if !timescale.is_finite() || timescale < 0.0 {
        anyhow::bail!("--sim-timescale must be a finite non-negative number, got {timescale}");
    }
    let rps_list = parse_rps(args)?;

    if replicate.is_some() && cards < 2 {
        anyhow::bail!("--replicate needs --cards > 1 (a replica lives on another card)");
    }
    if cards > 1 {
        // --policy and --windows configure a single card's plan; silently
        // ignoring them against a fleet would mislabel the sweep.
        if args.flag("policy").is_some() || args.flag("windows").is_some() {
            anyhow::bail!(
                "--policy/--windows are per-card settings; with --cards > 1 every card \
                 uses group-to-chunk over its reach-derived window plan"
            );
        }
        return bench_serve_fleet(
            cards,
            adaptive,
            resplit,
            remap,
            replicate,
            skew,
            placer_name,
            rps_list,
            rows_per_request,
            duration,
            max_requests,
            timescale,
            args.u64_flag("verify", 0)?,
        );
    }

    let machine = machine_with_seed(0xA100)?;
    let map = TopologyMap::ground_truth(&machine);
    let rows = 32_768u64 * windows as u64;
    let table = Table::synthetic(rows, SERVE_D);
    let plan = WindowPlan::split(rows, (SERVE_D * 4) as u64, windows);
    // Probed timing: load generation measures the serving pipeline's
    // wall-clock behavior; skip per-window DES calibration at startup.
    let mut cfg = SimBackendConfig::new(policy);
    cfg.adaptive = adaptive;
    cfg.resplit = resplit;
    cfg.remap = remap.clone();
    cfg.sim_timescale = timescale;
    let backend = Arc::new(SimBackend::start(
        cfg,
        &map,
        plan,
        table.view(),
        SimTiming::Probed,
    )?);
    let service = Service::new(backend.clone());

    println!(
        "open-loop sweep: policy {policy}, placer {placer_name}, skew {skew:?}, \
         {windows} windows, {rows_per_request} rows/request, {} ms per point{}",
        duration.as_millis(),
        if timescale > 0.0 {
            format!(", paced at {timescale}x sim time")
        } else {
            String::new()
        }
    );
    println!(
        "{:>12} {:>12} {:>10} {:>10} {:>8} {:>8}",
        "offered_rps", "achieved_rps", "mean_us", "p99_us", "dropped", "errors"
    );
    for offered in rps_list {
        let mut gen = RequestGen::new(WorkloadSpec {
            total_rows: rows,
            distribution: skew.clone(),
            request_rows: (rows_per_request, rows_per_request),
            seed: 42,
        });
        let cfg = OpenLoopConfig {
            duration,
            max_requests,
            ..OpenLoopConfig::default()
        };
        let p = drive(&service, &mut gen, offered, &cfg);
        println!(
            "{:>12.0} {:>12.0} {:>10.0} {:>10} {:>8} {:>8}",
            p.offered_rps, p.achieved_rps, p.mean_latency_us, p.p99_latency_us, p.dropped, p.errors
        );
    }
    let m = service.metrics();
    println!("{}", m.report());
    let live_plan = backend.plan();
    let shown = live_plan.count().min(m.window_rows.len());
    println!(
        "per-window routed rows: {:?} ({} windows, placement generation {})",
        &m.window_rows[..shown],
        live_plan.count(),
        backend.placement().generation
    );
    println!(
        "simulated aggregate (makespan over groups): {:.1} GB/s",
        backend.aggregate_sim_gbps()
    );
    if remap.is_some() {
        let rp = backend.remap_plan();
        println!(
            "remap: generation {}, {} packed window(s), {} hot rows in page-aligned prefixes",
            rp.generation,
            rp.packed_windows(),
            rp.total_hot_rows()
        );
    }
    if placer_name != "static" {
        print_decision_trace("card", &backend.control_decisions());
    }
    let verify_n = args.u64_flag("verify", 0)?;
    if verify_n > 0 {
        // Regression guard: the sweep above ran open-loop (results
        // discarded); now prove merged-row correctness on the very same
        // live backend, then check the repartition counter invariant.
        let verified = serve_requests(
            |rows| {
                let ticket = service.submit(rows, None)?;
                Ok(Box::new(move || ticket.wait()))
            },
            &table,
            verify_n,
            rows_per_request,
        )?;
        assert_repartition_counters("card", || service.metrics())?;
        if placer_name != "static" {
            anyhow::ensure!(
                !backend.control_decisions().is_empty(),
                "adaptive sweep produced no control-plane decisions"
            );
        }
        // Whatever remap plan is live after the verified traffic must be a
        // true in-window permutation (identity plans pass trivially).
        backend
            .remap_plan()
            .check(&backend.plan())
            .map_err(|e| anyhow::anyhow!("published remap plan violates invariants: {e:#}"))?;
        println!("verify: {verify_n} requests ({verified} rows) checked; counters consistent");
    }
    service.shutdown();
    Ok(())
}

/// The bench-serve `--verify` counter invariant: every published
/// generation in a registry is attributable to exactly one lever.  The
/// lever counter and `generations_published` are two separate relaxed
/// increments, so a still-running background epoch thread can be observed
/// between the pair — re-snapshot briefly before declaring the counters
/// inconsistent.
fn assert_repartition_counters(
    scope: &str,
    snapshot: impl Fn() -> a100win::coordinator::MetricsSnapshot,
) -> anyhow::Result<()> {
    let mut last = (0, 0);
    for _ in 0..40 {
        let m = snapshot();
        let levers = m.redeal_epochs
            + m.resplit_epochs
            + m.migrate_epochs
            + m.repack_epochs
            + m.replicate_epochs;
        if m.generations_published == levers {
            return Ok(());
        }
        last = (m.generations_published, levers);
        std::thread::sleep(Duration::from_millis(5));
    }
    anyhow::bail!(
        "{scope}: generations_published={} but \
         redeal+resplit+migrate+repack+replicate={} (never converged)",
        last.0,
        last.1
    )
}

/// Tail of a control plane's audited decision trace.
fn print_decision_trace(scope: &str, decisions: &[Decision]) {
    const SHOW: usize = 8;
    let skip = decisions.len().saturating_sub(SHOW);
    println!(
        "{scope} control plane: {} decisions (showing last {})",
        decisions.len(),
        decisions.len() - skip
    );
    for d in &decisions[skip..] {
        println!(
            "  epoch {:>4}: permitted {:>7}, acted {:<7} imbalance {:.3}{} — {}",
            d.epoch,
            d.permitted.to_string(),
            d.acted.map_or_else(|| "-".to_string(), |l| l.to_string()),
            d.imbalance,
            d.generation.map_or_else(String::new, |g| format!(" gen {g}")),
            d.why
        );
    }
}

/// bench-serve against a fleet: the full two-level-plus-migration control
/// plane under open-loop load (sim-backed, hermetic).
#[allow(clippy::too_many_arguments)]
fn bench_serve_fleet(
    cards: usize,
    adaptive: Option<AdaptiveConfig>,
    resplit: Option<SplitterConfig>,
    remap: Option<RemapConfig>,
    replicate: Option<ReplicateConfig>,
    skew: Distribution,
    placer_name: &str,
    rps_list: Vec<f64>,
    rows_per_request: usize,
    duration: Duration,
    max_requests: Option<u64>,
    sim_timescale: f64,
    verify_n: u64,
) -> anyhow::Result<()> {
    // Probe map per card: enumeration seeds differ card to card (paper
    // §1.1), so each shard gets its own TopologyMap + placement.
    let mut specs = Vec::new();
    for i in 0..cards {
        let machine = machine_with_seed(0xA100 + 0x1111 * i as u64)?;
        let spec = CardSpec {
            map: TopologyMap::ground_truth(&machine),
            memory_bytes: machine.config().memory.total_bytes,
        };
        specs.push((spec, SimTiming::Probed));
    }
    let rows = 32_768u64 * cards as u64;
    let table = Table::synthetic(rows, SERVE_D);
    // build_sim_with strips the per-card epoch timer itself: its fleet
    // epoch thread is the one driver of every card's control plane.  The
    // static arm pins the shard map too (max_lever Hold) so it stays an
    // honest baseline — no migrations behind a "static" label — unless
    // --replicate was asked for explicitly (build_sim_with then raises
    // the ceiling to the fifth rung).
    let fleet_control = ControlPlaneConfig {
        max_lever: if placer_name == "static" && replicate.is_none() {
            Lever::Hold
        } else {
            Lever::Migrate
        },
        ..ControlPlaneConfig::default()
    };
    let replicate_armed = replicate.is_some();
    let fleet = FleetService::build_sim_with(
        specs,
        &table,
        FleetConfig {
            adaptive,
            resplit,
            remap,
            replicate,
            control: fleet_control,
            epoch: Some(Duration::from_millis(20)),
            sim_timescale,
            ..FleetConfig::default()
        },
    )?;
    println!(
        "fleet open-loop sweep: {cards} cards, placer {placer_name}, skew {skew:?}, \
         {rows_per_request} rows/request, {} ms per point, control epochs every 20 ms",
        duration.as_millis()
    );
    println!(
        "{:>12} {:>12} {:>10} {:>10} {:>8} {:>8}",
        "offered_rps", "achieved_rps", "mean_us", "p99_us", "dropped", "errors"
    );
    for offered in rps_list {
        let mut gen = RequestGen::new(WorkloadSpec {
            total_rows: rows,
            distribution: skew.clone(),
            request_rows: (rows_per_request, rows_per_request),
            seed: 42,
        });
        let cfg = OpenLoopConfig {
            duration,
            max_requests,
            ..OpenLoopConfig::default()
        };
        let p = drive(&fleet, &mut gen, offered, &cfg);
        println!(
            "{:>12.0} {:>12.0} {:>10.0} {:>10} {:>8} {:>8}",
            p.offered_rps, p.achieved_rps, p.mean_latency_us, p.p99_latency_us, p.dropped, p.errors
        );
    }
    let plan = fleet.plan();
    println!(
        "fleet plan generation {} ({} shards):",
        plan.generation,
        plan.shards.len()
    );
    for s in &plan.shards {
        println!(
            "  card {}: rows [{}, {}) in {} windows",
            s.card,
            s.start_row,
            s.end_row(),
            s.plan.count()
        );
    }
    println!("fleet: {}", fleet.fleet_metrics().report());
    for (card, m) in fleet.per_card_metrics() {
        println!("  card {card}: {}", m.report());
    }
    if replicate_armed {
        let rs = fleet.replica_set();
        println!(
            "replica set: generation {}, {} live replica(s)",
            rs.generation,
            rs.count()
        );
        for (shard, card, svc) in fleet.replica_cards() {
            println!("  shard {shard} replicated on card {card}: {}", svc.metrics().report());
        }
        let depths = fleet.queue_depths();
        println!("queue depths (per card): {depths:?}");
    }
    println!(
        "aggregate simulated GB/s (sum over cards): {:.1}",
        fleet.aggregate_sim_gbps()
    );
    print_decision_trace("fleet", &fleet.control_decisions());
    if verify_n > 0 {
        // Regression guard: merged-row correctness on the live (possibly
        // migrated) fleet, then the counter invariant per registry.
        let verified = serve_requests(
            |rows| {
                let ticket = fleet.submit(rows, None)?;
                Ok(Box::new(move || ticket.wait()))
            },
            &table,
            verify_n,
            rows_per_request,
        )?;
        assert_repartition_counters("fleet", || fleet.fleet_metrics())?;
        let card_ids: Vec<usize> = fleet.plan().shards.iter().map(|s| s.card).collect();
        for (card, svc) in card_ids.into_iter().zip(fleet.cards()) {
            assert_repartition_counters(&format!("card {card}"), || svc.metrics())?;
        }
        for (shard, card, svc) in fleet.replica_cards() {
            assert_repartition_counters(
                &format!("replica of shard {shard} on card {card}"),
                || svc.metrics(),
            )?;
        }
        if placer_name != "static" {
            anyhow::ensure!(
                !fleet.control_decisions().is_empty(),
                "adaptive fleet sweep produced no control-plane decisions"
            );
        }
        println!(
            "verify: {verify_n} requests ({verified} rows) merged in order; counters consistent"
        );
    }
    fleet.shutdown();
    Ok(())
}

/// Chaos soak (`bench-serve --chaos`): drive a seeded fault schedule
/// against the fully armed resilience stack under drifting zipf load and
/// verify every delivered row against the table (EXPERIMENTS.md §Chaos).
fn cmd_chaos(args: &Args) -> anyhow::Result<()> {
    let seed = args.u64_flag("seed", 7)?;
    let cards = args.u64_flag("cards", 1)? as usize;
    let requests = args.u64_flag("requests", 400)? as usize;
    let rows_per_request = (args.u64_flag("rows-per-request", 96)? as usize).max(1);
    let windows = args.u64_flag("windows", 4)? as usize;
    let timescale = args.f64_flag("sim-timescale", 8.0)?;
    if !timescale.is_finite() || timescale < 0.0 {
        anyhow::bail!("--sim-timescale must be a finite non-negative number, got {timescale}");
    }
    let deadline = Duration::from_millis(args.u64_flag("deadline-ms", 25)?);
    let verify_n = args.u64_flag("verify", 0)?;

    let chaos_cfg = ChaosConfig {
        requests,
        request_rows: ((rows_per_request / 4).max(1), rows_per_request),
        distribution: Distribution::parse("drift:zipf:1.1:400")?,
        seed,
        deadline: Some(deadline),
        concurrency: 8,
    };

    if cards > 1 {
        return chaos_fleet(cards, timescale, seed, chaos_cfg, deadline, verify_n);
    }

    let machine = machine_with_seed(0xA100)?;
    let map = TopologyMap::ground_truth(&machine);
    let groups = map.groups.len();
    let rows = 32_768u64 * windows as u64;
    let table = Table::synthetic(rows, SERVE_D);
    let plan = WindowPlan::split(rows, (SERVE_D * 4) as u64, windows);
    let mut cfg = SimBackendConfig::new(PlacementPolicy::parse("group-to-chunk")?);
    cfg.adaptive = Some(AdaptiveConfig {
        epoch: Some(Duration::from_millis(20)),
        ..AdaptiveConfig::default()
    });
    cfg.sim_timescale = timescale;
    cfg.resilience = ResilienceConfig::full();
    cfg.fault = Some(FaultPlan::chaos(seed, groups));
    let backend = Arc::new(SimBackend::start(
        cfg,
        &map,
        plan,
        table.view(),
        SimTiming::Probed,
    )?);
    let service = Service::new(backend.clone());

    println!(
        "chaos soak: 1 card ({groups} groups), seed {seed}, {requests} requests of up to \
         {rows_per_request} rows, drift:zipf load, deadline {} ms, paced at {timescale}x sim time",
        deadline.as_millis()
    );
    let report = drive_chaos(&service, &table, &chaos_cfg);
    print_chaos_report("soak", &report, deadline)?;
    if let Some((stalls, fails)) = backend.faults_injected() {
        println!("injected faults: {stalls} stalls, {fails} hard failures");
    }
    println!("{}", service.metrics().report());
    print_decision_trace("card", &backend.control_decisions());

    if verify_n > 0 {
        let vreport = drive_chaos(
            &service,
            &table,
            &ChaosConfig {
                requests: verify_n as usize,
                request_rows: (rows_per_request, rows_per_request),
                distribution: Distribution::Uniform,
                seed: seed ^ 0xC0FFEE,
                deadline: None,
                concurrency: 4,
            },
        );
        print_chaos_report("verify", &vreport, deadline)?;
        println!(
            "verify: {verify_n} requests checked against the table after the soak settled"
        );
    }
    service.shutdown();
    Ok(())
}

/// Fleet flavor of the chaos soak: every card gets its own decorrelated
/// slice of the fault schedule ([`FaultPlan::for_card`]); partial results
/// merge across cards in request order.
fn chaos_fleet(
    cards: usize,
    timescale: f64,
    seed: u64,
    chaos_cfg: ChaosConfig,
    deadline: Duration,
    verify_n: u64,
) -> anyhow::Result<()> {
    let mut specs = Vec::new();
    for i in 0..cards {
        let machine = machine_with_seed(0xA100 + 0x1111 * i as u64)?;
        let spec = CardSpec {
            map: TopologyMap::ground_truth(&machine),
            memory_bytes: machine.config().memory.total_bytes,
        };
        specs.push((spec, SimTiming::Probed));
    }
    let groups = specs[0].0.map.groups.len();
    let rows = 32_768u64 * cards as u64;
    let table = Table::synthetic(rows, SERVE_D);
    let fleet = FleetService::build_sim_with(
        specs,
        &table,
        FleetConfig {
            adaptive: Some(AdaptiveConfig {
                epoch: Some(Duration::from_millis(20)),
                ..AdaptiveConfig::default()
            }),
            epoch: Some(Duration::from_millis(20)),
            sim_timescale: timescale,
            resilience: ResilienceConfig::full(),
            fault: Some(FaultPlan::chaos(seed, groups)),
            ..FleetConfig::default()
        },
    )?;

    println!(
        "chaos soak: {cards} cards ({groups} groups each), seed {seed}, {} requests of up to \
         {} rows, drift:zipf load, deadline {} ms, paced at {timescale}x sim time",
        chaos_cfg.requests,
        chaos_cfg.request_rows.1,
        deadline.as_millis()
    );
    let report = drive_chaos(&fleet, &table, &chaos_cfg);
    print_chaos_report("soak", &report, deadline)?;
    println!("fleet: {}", fleet.fleet_metrics().report());
    for (card, m) in fleet.per_card_metrics() {
        println!("  card {card}: {}", m.report());
    }
    print_decision_trace("fleet", &fleet.control_decisions());

    if verify_n > 0 {
        let vreport = drive_chaos(
            &fleet,
            &table,
            &ChaosConfig {
                requests: verify_n as usize,
                request_rows: (chaos_cfg.request_rows.1, chaos_cfg.request_rows.1),
                distribution: Distribution::Uniform,
                seed: seed ^ 0xC0FFEE,
                deadline: None,
                concurrency: 4,
            },
        );
        print_chaos_report("verify", &vreport, deadline)?;
        println!(
            "verify: {verify_n} requests merged in request order after the soak settled"
        );
    }
    fleet.shutdown();
    Ok(())
}

/// Print a soak report and enforce the chaos acceptance contract: zero
/// corrupted rows, zero malformed masks, no total outage, and bounded
/// failure-resolution tail.
fn print_chaos_report(scope: &str, r: &ChaosReport, deadline: Duration) -> anyhow::Result<()> {
    println!(
        "{scope}: {} full, {} partial, {} failed (goodput {:.1}%)",
        r.completed,
        r.partials,
        r.failed,
        r.goodput() * 100.0
    );
    println!(
        "  rows: {} verified exact, {} masked out (zero-filled), {} corrupted, \
         {} mask violations",
        r.valid_rows_checked, r.invalid_rows, r.corrupted_rows, r.mask_violations
    );
    println!(
        "  p99: {} us to succeed, {} us to resolve a failure",
        r.p99_us, r.failure_p99_us
    );
    anyhow::ensure!(
        r.corrupted_rows == 0 && r.mask_violations == 0,
        "{scope}: delivered corrupted rows ({}) or malformed masks ({})",
        r.corrupted_rows,
        r.mask_violations
    );
    anyhow::ensure!(
        r.completed + r.partials > 0,
        "{scope}: total outage — no request delivered any data"
    );
    // Failures must resolve fast: timeout path is bounded by the deadline,
    // the fast-fail path by the retry budget's backoff ladder.  The bound
    // is generous (4x deadline + scheduling slack) but real.
    let bound = deadline * 4 + Duration::from_millis(100);
    anyhow::ensure!(
        r.failed == 0 || u128::from(r.failure_p99_us) <= bound.as_micros(),
        "{scope}: failure-resolution p99 {} us exceeds bound {} us",
        r.failure_p99_us,
        bound.as_micros()
    );
    Ok(())
}

/// The QPS ladder shared by the local and remote sweeps.
fn parse_rps(args: &Args) -> anyhow::Result<Vec<f64>> {
    match args.flag("rps") {
        None => Ok(vec![1_000.0, 4_000.0, 16_000.0, 64_000.0]),
        Some(s) => s
            .split(',')
            .map(|x| {
                x.trim()
                    .parse()
                    .map_err(|_| anyhow::anyhow!("--rps expects numbers, got '{x}'"))
            })
            .collect(),
    }
}

/// Build a sim-backed serving target (one card or a fleet) and put the
/// network edge in front of it.  `fault` sees the backend's group count
/// and may return a deterministic fault schedule; `resilient` arms the
/// full retry/hedge/partial/breaker stack plus adaptive epoching (so
/// health flaps are observed and healed).  The single-card path wires a
/// `/readyz` probe to group health: ready while at least one group is
/// live, so a balancer stops routing on total outage before clients see
/// errors.
fn start_net_server(
    cards: usize,
    windows: usize,
    rows_per_window: u64,
    timescale: f64,
    fault: impl FnOnce(usize) -> Option<FaultPlan>,
    resilient: bool,
    net: NetConfig,
) -> anyhow::Result<(NetServer, Table)> {
    if cards > 1 {
        let mut specs = Vec::new();
        for i in 0..cards {
            let machine = machine_with_seed(0xA100 + 0x1111 * i as u64)?;
            let spec = CardSpec {
                map: TopologyMap::ground_truth(&machine),
                memory_bytes: machine.config().memory.total_bytes,
            };
            specs.push((spec, SimTiming::Probed));
        }
        let groups = specs[0].0.map.groups.len();
        let rows = rows_per_window * cards as u64;
        let table = Table::synthetic(rows, SERVE_D);
        let mut cfg = FleetConfig {
            epoch: Some(Duration::from_millis(20)),
            sim_timescale: timescale,
            fault: fault(groups),
            ..FleetConfig::default()
        };
        if resilient {
            cfg.adaptive = Some(AdaptiveConfig {
                epoch: Some(Duration::from_millis(20)),
                ..AdaptiveConfig::default()
            });
            cfg.resilience = ResilienceConfig::full();
        }
        let fleet = Arc::new(FleetService::build_sim_with(specs, &table, cfg)?);
        let server = NetServer::start(Target::Fleet(fleet), net)?;
        Ok((server, table))
    } else {
        let machine = machine_with_seed(0xA100)?;
        let map = TopologyMap::ground_truth(&machine);
        let groups = map.groups.len();
        let rows = rows_per_window * windows.max(1) as u64;
        let table = Table::synthetic(rows, SERVE_D);
        let plan = WindowPlan::split(rows, (SERVE_D * 4) as u64, windows);
        let mut cfg = SimBackendConfig::new(PlacementPolicy::parse("group-to-chunk")?);
        cfg.sim_timescale = timescale;
        cfg.fault = fault(groups);
        if resilient {
            cfg.adaptive = Some(AdaptiveConfig {
                epoch: Some(Duration::from_millis(20)),
                ..AdaptiveConfig::default()
            });
            cfg.resilience = ResilienceConfig::full();
        }
        let backend = Arc::new(SimBackend::start(
            cfg,
            &map,
            plan,
            table.view(),
            SimTiming::Probed,
        )?);
        let probe_backend = Arc::clone(&backend);
        let ready: a100win::net::server::ReadyProbe = Box::new(move || {
            probe_backend
                .health_state()
                .health
                .iter()
                .any(|h| !matches!(h, GroupHealth::Failed))
        });
        let server =
            NetServer::start_with_probe(Target::Single(Service::new(backend)), net, Some(ready))?;
        Ok((server, table))
    }
}

fn cmd_serve_net(args: &Args) -> anyhow::Result<()> {
    let port = args.u64_flag("port", 0)?;
    let http_port = args.u64_flag("http-port", 0)?;
    let cards = args.u64_flag("cards", 1)? as usize;
    let windows = args.u64_flag("windows", 2)? as usize;
    let rows_per_window = args.u64_flag("rows-per-window", 32_768)?;
    let max_conns = args.u64_flag("max-conns", 64)? as usize;
    let global_slots = args.u64_flag("global-slots", 256)? as usize;
    let timescale = args.f64_flag("sim-timescale", 0.0)?;
    if !timescale.is_finite() || timescale < 0.0 {
        anyhow::bail!("--sim-timescale must be a finite non-negative number, got {timescale}");
    }
    let selfcheck = args.u64_flag("selfcheck", 0)?;
    let duration = Duration::from_millis(args.u64_flag("duration-ms", 2_000)?);
    let drain_budget = Duration::from_millis(args.u64_flag("drain-ms", 5_000)?);

    let net = NetConfig {
        addr: format!("127.0.0.1:{port}"),
        http_addr: Some(format!("127.0.0.1:{http_port}")),
        max_conns,
        global_slots,
        ..NetConfig::default()
    };
    let (mut server, table) =
        start_net_server(cards, windows, rows_per_window, timescale, |_| None, false, net)?;
    println!(
        "serve-net: binary protocol on {}, http on {} ({} rows x {} f32, {} card{})",
        server.addr(),
        server
            .http_addr()
            .map(|a| a.to_string())
            .unwrap_or_else(|| "-".into()),
        table.rows,
        SERVE_D,
        cards,
        if cards == 1 { "" } else { "s" }
    );
    if selfcheck > 0 {
        selfcheck_net(&server, &table, selfcheck)?;
    } else {
        std::thread::sleep(duration);
    }
    let report = server.drain(drain_budget);
    println!(
        "drain: completed={} after {} ms ({} in flight at start, {} conns refused)",
        report.completed,
        report.waited.as_millis(),
        report.in_flight_at_start,
        report.refused_conns
    );
    println!("net: {}", server.metrics());
    server.shutdown();
    anyhow::ensure!(report.completed, "graceful drain left in-flight work behind");
    Ok(())
}

/// `serve-net --selfcheck N`: N verified lookups over loopback TCP, then
/// `/healthz`, `/readyz`, and one JSON lookup over the HTTP channel.
fn selfcheck_net(server: &NetServer, table: &Table, n: u64) -> anyhow::Result<()> {
    let addr = server.addr().to_string();
    let mut client = NetClient::connect(&addr, ClientConfig::default())?;
    anyhow::ensure!(
        client.d() == table.d && client.rows() == table.rows,
        "HelloAck shape mismatch: ({}, {}) vs table ({}, {})",
        client.d(),
        client.rows(),
        table.d,
        table.rows
    );
    let d = table.d;
    let mut gen = RequestGen::new(WorkloadSpec::uniform(table.rows, 64, 11));
    let mut verified = 0u64;
    for _ in 0..n {
        let rows = gen.next_request();
        match client.lookup(&rows, None)? {
            Outcome::Full(data) => {
                anyhow::ensure!(data.len() == rows.len() * d, "short response");
                for (k, &row) in rows.iter().enumerate() {
                    for j in 0..d {
                        anyhow::ensure!(
                            data[k * d + j] == table.expected(row, j),
                            "row {row} column {j}: got {} want {}",
                            data[k * d + j],
                            table.expected(row, j)
                        );
                    }
                }
                verified += rows.len() as u64;
            }
            Outcome::Partial { .. } => {
                anyhow::bail!("selfcheck got a partial result with no deadline and no faults")
            }
        }
    }
    println!("selfcheck: {n} TCP requests, {verified} rows verified");

    let Some(http) = server.http_addr() else {
        return Ok(());
    };
    let http = http.to_string();
    let (status, body) = http_request(
        &http,
        "GET /healthz HTTP/1.1\r\nHost: a100win\r\nConnection: close\r\n\r\n",
    )?;
    let state = Json::parse(&body)
        .ok()
        .and_then(|j| j.get("state").and_then(Json::as_str).map(String::from));
    anyhow::ensure!(
        status == 200 && state.as_deref() == Some("serving"),
        "healthz: status {status}, state {state:?}"
    );
    let (status, _) = http_request(
        &http,
        "GET /readyz HTTP/1.1\r\nHost: a100win\r\nConnection: close\r\n\r\n",
    )?;
    anyhow::ensure!(status == 200, "readyz: not ready (status {status})");
    let lookup_body = "{\"rows\":[0,1,2]}";
    let req = format!(
        "POST /v1/lookup HTTP/1.1\r\nHost: a100win\r\nContent-Length: {}\r\n\
         Connection: close\r\n\r\n{lookup_body}",
        lookup_body.len()
    );
    let (status, body) = http_request(&http, &req)?;
    anyhow::ensure!(status == 200, "http lookup: status {status}, body {body}");
    let parsed = Json::parse(&body)?;
    let data = parsed
        .get("data")
        .and_then(Json::as_arr)
        .ok_or_else(|| anyhow::anyhow!("http lookup response has no \"data\": {body}"))?;
    anyhow::ensure!(
        data.len() == 3 * d,
        "http lookup: {} values for 3 rows of d={d}",
        data.len()
    );
    for (k, row) in (0u64..3).enumerate() {
        for j in 0..d {
            let got = data[k * d + j]
                .as_f64()
                .ok_or_else(|| anyhow::anyhow!("non-numeric value in \"data\""))?;
            anyhow::ensure!(
                got as f32 == table.expected(row, j),
                "http lookup row {row} column {j}: got {got} want {}",
                table.expected(row, j)
            );
        }
    }
    println!("selfcheck: /healthz, /readyz, and a JSON lookup verified");
    Ok(())
}

/// Minimal HTTP client for the selfcheck: one request, `Connection:
/// close`, returns (status, body).
fn http_request(addr: &str, request: &str) -> anyhow::Result<(u16, String)> {
    use std::io::{Read as _, Write as _};
    let mut stream = std::net::TcpStream::connect(addr)?;
    stream.set_read_timeout(Some(Duration::from_secs(5)))?;
    stream.set_write_timeout(Some(Duration::from_secs(5)))?;
    stream.write_all(request.as_bytes())?;
    let mut resp = String::new();
    stream.read_to_string(&mut resp)?;
    let status: u16 = resp
        .strip_prefix("HTTP/1.1 ")
        .and_then(|r| r.get(..3))
        .and_then(|code| code.parse().ok())
        .ok_or_else(|| anyhow::anyhow!("malformed HTTP response: {resp:.60}"))?;
    let body = resp
        .split_once("\r\n\r\n")
        .map(|(_, b)| b.to_string())
        .unwrap_or_default();
    Ok((status, body))
}

/// `bench-serve --remote`: the open-loop sweep (or chaos soak) through
/// the network front door — an in-process `serve-net` server on loopback
/// driven by a pooled binary-protocol client.
fn cmd_bench_remote(args: &Args) -> anyhow::Result<()> {
    if args.u64_flag("cards", 1)? > 1 {
        anyhow::bail!("--remote drives a single-card server; drop --cards");
    }
    for f in ["placer", "remap", "replicate", "policy"] {
        anyhow::ensure!(
            !args.bool_flag(f),
            "--{f} does not apply to --remote (the server pins group-to-chunk placement)"
        );
    }
    if args.bool_flag("chaos") {
        return remote_chaos(args);
    }
    let windows = args.u64_flag("windows", 2)? as usize;
    let rows_per_request = args.u64_flag("rows-per-request", 256)? as usize;
    let duration = Duration::from_millis(args.u64_flag("duration-ms", 300)?);
    let max_requests = match args.u64_flag("requests", 0)? {
        0 => None,
        n => Some(n),
    };
    let timescale = args.f64_flag("sim-timescale", 0.0)?;
    if !timescale.is_finite() || timescale < 0.0 {
        anyhow::bail!("--sim-timescale must be a finite non-negative number, got {timescale}");
    }
    let conns = (args.u64_flag("conns", 8)? as usize).max(1);
    let skew = match args.flag("skew-drift") {
        Some(spec) => Distribution::parse(spec)?,
        None => Distribution::parse(args.flag("skew").unwrap_or("uniform"))?,
    };
    let rps_list = parse_rps(args)?;

    let (mut server, table) =
        start_net_server(1, windows, 32_768, timescale, |_| None, false, NetConfig::default())?;
    let pool = RemotePool::new(server.addr().to_string(), ClientConfig::default(), conns);
    let warmed = pool.connect_warm(conns)?;
    let (d, rows) = pool.probe()?;
    anyhow::ensure!(
        d == table.d && rows == table.rows,
        "HelloAck shape mismatch: ({d}, {rows}) vs table ({}, {})",
        table.d,
        table.rows
    );
    println!(
        "remote open-loop sweep: {} on loopback TCP, {warmed} pooled conns, skew {skew:?}, \
         {windows} windows, {rows_per_request} rows/request, {} ms per point{}",
        server.addr(),
        duration.as_millis(),
        if timescale > 0.0 {
            format!(", paced at {timescale}x sim time")
        } else {
            String::new()
        }
    );
    println!(
        "{:>12} {:>12} {:>10} {:>10} {:>8} {:>8}",
        "offered_rps", "achieved_rps", "mean_us", "p99_us", "dropped", "errors"
    );
    for offered in rps_list {
        let mut gen = RequestGen::new(WorkloadSpec {
            total_rows: table.rows,
            distribution: skew.clone(),
            request_rows: (rows_per_request, rows_per_request),
            seed: 42,
        });
        let cfg = OpenLoopConfig {
            duration,
            max_requests,
            ..OpenLoopConfig::default()
        };
        let p = drive(&pool, &mut gen, offered, &cfg);
        println!(
            "{:>12.0} {:>12.0} {:>10.0} {:>10} {:>8} {:>8}",
            p.offered_rps, p.achieved_rps, p.mean_latency_us, p.p99_latency_us, p.dropped, p.errors
        );
    }

    let verify_n = args.u64_flag("verify", 0)?;
    if verify_n > 0 {
        // Same regression guard as the local sweep, through the wire:
        // every returned row decoded from frames and checked.
        let vreport = drive_chaos(
            &pool,
            &table,
            &ChaosConfig {
                requests: verify_n as usize,
                request_rows: (rows_per_request, rows_per_request),
                distribution: Distribution::Uniform,
                seed: 0xC0FFEE,
                deadline: None,
                concurrency: 4,
            },
        );
        anyhow::ensure!(
            vreport.failed == 0 && vreport.partials == 0,
            "remote verify: {} failures, {} partials on a clean loopback path",
            vreport.failed,
            vreport.partials
        );
        anyhow::ensure!(
            vreport.corrupted_rows == 0 && vreport.mask_violations == 0,
            "remote verify delivered corrupted rows: {vreport:?}"
        );
        println!(
            "verify: {verify_n} requests ({} rows) checked over the wire",
            vreport.valid_rows_checked
        );
    }
    println!("net: {}", server.metrics());
    println!("pool: {} conns dialed for {} slots", pool.dials(), conns);
    let report = server.drain(Duration::from_secs(10));
    println!(
        "drain: completed={} after {} ms ({} in flight at start, {} conns refused)",
        report.completed,
        report.waited.as_millis(),
        report.in_flight_at_start,
        report.refused_conns
    );
    server.shutdown();
    anyhow::ensure!(report.completed, "graceful drain left in-flight work behind");
    Ok(())
}

/// `bench-serve --remote --chaos`: backend faults (stalls, outages,
/// flapping health) *and* client-side transport faults (torn frames,
/// half-closes, dropped connections) fire together against the armed
/// resilience stack; every delivered row is verified, then the run ends
/// with a drain-under-load demonstration.
fn remote_chaos(args: &Args) -> anyhow::Result<()> {
    let seed = args.u64_flag("seed", 7)?;
    let requests = args.u64_flag("requests", 400)? as usize;
    let rows_per_request = (args.u64_flag("rows-per-request", 96)? as usize).max(1);
    let windows = args.u64_flag("windows", 4)? as usize;
    let timescale = args.f64_flag("sim-timescale", 8.0)?;
    if !timescale.is_finite() || timescale < 0.0 {
        anyhow::bail!("--sim-timescale must be a finite non-negative number, got {timescale}");
    }
    let deadline = Duration::from_millis(args.u64_flag("deadline-ms", 250)?);
    let verify_n = args.u64_flag("verify", 0)?;
    let conns = (args.u64_flag("conns", 8)? as usize).max(1);

    let (mut server, table) = start_net_server(
        1,
        windows,
        32_768,
        timescale,
        |groups| Some(FaultPlan::chaos(seed, groups)),
        true,
        NetConfig::default(),
    )?;
    let pool = RemotePool::with_faults(
        server.addr().to_string(),
        ClientConfig::default(),
        conns,
        NetFaultPlan::chaos(seed),
    );
    println!(
        "remote chaos soak: seed {seed}, {requests} requests of up to {rows_per_request} rows \
         over {conns} loopback conns, backend + transport faults, deadline {} ms, \
         paced at {timescale}x sim time",
        deadline.as_millis()
    );
    let report = drive_chaos(
        &pool,
        &table,
        &ChaosConfig {
            requests,
            request_rows: ((rows_per_request / 4).max(1), rows_per_request),
            distribution: Distribution::parse("drift:zipf:1.1:400")?,
            seed,
            deadline: Some(deadline),
            concurrency: 8,
        },
    );
    print_chaos_report("net-soak", &report, deadline)?;
    println!(
        "pool: {} conns dialed for {} slots (re-dials replace poisoned conns)",
        pool.dials(),
        conns
    );
    println!("net: {}", server.metrics());

    if verify_n > 0 {
        // Fresh pool, no transport faults, no deadline: after the soak
        // settles every row must come back exact.
        let clean = RemotePool::new(server.addr().to_string(), ClientConfig::default(), 4);
        let vreport = drive_chaos(
            &clean,
            &table,
            &ChaosConfig {
                requests: verify_n as usize,
                request_rows: (rows_per_request, rows_per_request),
                distribution: Distribution::Uniform,
                seed: seed ^ 0xC0FFEE,
                deadline: None,
                concurrency: 4,
            },
        );
        print_chaos_report("net-verify", &vreport, deadline)?;
        println!("verify: {verify_n} requests checked over a clean connection pool");
    }

    let drained = server.drain(Duration::from_secs(10));
    println!(
        "drain: completed={} after {} ms ({} in flight at start, {} conns refused)",
        drained.completed,
        drained.waited.as_millis(),
        drained.in_flight_at_start,
        drained.refused_conns
    );
    server.shutdown();
    anyhow::ensure!(drained.completed, "graceful drain left in-flight work behind");

    drain_under_load_demo(seed)
}

/// The acceptance demo for the drain lifecycle, on a fresh server whose
/// every group is stalled hard (paced wall clock makes one request take
/// on the order of 100 ms): a drain started mid-request must wait for
/// it, refuse a new connection with an explicit `shed(draining)`
/// response, and report completion.  Resilience stays OFF so a hedge or
/// retry cannot shortcut the stall and close the observation window.
fn drain_under_load_demo(seed: u64) -> anyhow::Result<()> {
    let stall_all = |groups: usize| {
        let mut plan = FaultPlan::new(seed);
        for g in 0..groups {
            plan = plan.stall(g, 0, u64::MAX, StallKind::Fixed(200_000.0));
        }
        Some(plan)
    };
    let (mut server, table) =
        start_net_server(1, 2, 32_768, 20.0, stall_all, false, NetConfig::default())?;
    let addr = server.addr().to_string();
    let mut client = NetClient::connect(&addr, ClientConfig::default())?;
    let rows: Vec<u64> = (0..256u64).map(|i| (i * 97) % table.rows).collect();
    let rows_ref = &rows;

    let (outcome, in_flight_seen, drained, shed_msg) = std::thread::scope(|s| {
        let lookup = s.spawn(move || client.lookup(rows_ref, None));
        // Wait until the request is admitted before starting the drain.
        let mut in_flight_seen = 0;
        for _ in 0..5_000 {
            in_flight_seen = server.in_flight();
            if in_flight_seen > 0 {
                break;
            }
            std::thread::sleep(Duration::from_millis(1));
        }
        // Race a connect loop against the drain: once the state flips to
        // draining, a new connection must be *answered* with a shed
        // frame, not silently dropped.
        let addr = addr.clone();
        let shed_probe = s.spawn(move || {
            let give_up = Instant::now() + Duration::from_secs(20);
            loop {
                match NetClient::connect(&addr, ClientConfig::default()) {
                    Ok(_) => {
                        if Instant::now() >= give_up {
                            return String::new();
                        }
                        std::thread::sleep(Duration::from_millis(2));
                    }
                    Err(e) => return format!("{e:#}"),
                }
            }
        });
        let drained = server.drain(Duration::from_secs(30));
        let outcome = lookup
            .join()
            .map_err(|_| anyhow::anyhow!("lookup thread panicked"));
        let shed_msg = shed_probe
            .join()
            .map_err(|_| anyhow::anyhow!("shed probe thread panicked"));
        (outcome, in_flight_seen, drained, shed_msg)
    });

    let data = match outcome?? {
        Outcome::Full(data) => data,
        Outcome::Partial { .. } => anyhow::bail!("drain demo: stalled request came back partial"),
    };
    let d = table.d;
    anyhow::ensure!(data.len() == rows.len() * d, "drain demo: short response");
    for (k, &row) in rows.iter().enumerate() {
        for j in 0..d {
            anyhow::ensure!(
                data[k * d + j] == table.expected(row, j),
                "drain demo: row {row} column {j} corrupted"
            );
        }
    }
    let shed_msg = shed_msg?;
    anyhow::ensure!(
        in_flight_seen > 0,
        "drain demo: never observed the request in flight"
    );
    anyhow::ensure!(
        drained.completed,
        "drain demo: drain timed out with work in flight"
    );
    anyhow::ensure!(
        shed_msg.contains("shed(draining)"),
        "drain demo: mid-drain connection not refused with shed(draining); got '{shed_msg}'"
    );
    println!(
        "drain-under-load: in-flight request completed ({} rows verified), new connection \
         refused with shed(draining), drain waited {} ms",
        rows.len(),
        drained.waited.as_millis()
    );
    server.shutdown();
    Ok(())
}

fn cmd_remote(args: &Args) -> anyhow::Result<()> {
    use a100win::sim::nvlink::{run_remote, NvlinkConfig, PeerSpec};
    use a100win::sim::MemRegion;
    let peers = args.u64_flag("peers", 4)? as usize;
    let gib = args.u64_flag("region-gib", 80)?;
    let cfg = MachineConfig::a100_80gb();
    let nv = NvlinkConfig::a100();
    println!(
        "NVLink ingress: {:.0} GB/s, TLB reach {} GiB, {peers} peers reading {gib} GiB",
        nv.ingress_gbps,
        nv.reach_bytes(cfg.tlb.page_bytes) >> 30
    );
    let specs: Vec<PeerSpec> = (0..peers)
        .map(|_| PeerSpec {
            pattern: a100win::sim::Pattern::Uniform(MemRegion::new(0, gib << 30)),
        })
        .collect();
    let m = run_remote(&cfg, &nv, &specs, 20_000, 1);
    println!(
        "remote random access: {:.1} GB/s (TLB hit rate {:.3}, mean latency {:.0} ns)",
        m.gbps, m.tlb_hit_rate, m.avg_latency_ns
    );
    if m.tlb_hit_rate < 0.95 {
        println!("NOTE: the ingress TLB is a single shared structure; sender-side");
        println!("windowing cannot restore speed — shrink the total touched region.");
    }
    Ok(())
}

fn cmd_analytic(args: &Args) -> anyhow::Result<()> {
    use a100win::sim::analytic::Analytic;
    use a100win::sim::MemRegion;
    let gib = args.u64_flag("region-gib", 80)?;
    let cfg = MachineConfig::a100_80gb();
    let a = Analytic::new(&cfg);
    println!("closed-form predictions (no simulation), region {gib} GiB:");
    let p = a.predict_uniform(MemRegion::new(0, gib << 30), 128);
    println!(
        "  uniform random, all SMs: {:.0} GB/s (group 0: hit rate {:.3}, bottleneck {:?})",
        p.gbps, p.per_group[0].hit_rate, p.per_group[0].bottleneck
    );
    for txn in [128u64, 256, 512] {
        let p = a.predict_uniform(MemRegion::new(0, 32 << 30), txn);
        println!("  {txn:>4} B transactions over 32 GiB: {:.0} GB/s", p.gbps);
    }
    Ok(())
}

fn cmd_explain(args: &Args) -> anyhow::Result<()> {
    let seed = args.u64_flag("seed", 0xA100)?;
    let machine = machine_with_seed(seed)?;
    let cfg = machine.config();
    let topo = machine.topology();
    println!("simulated card: A100-SXM4-80GB (smid permutation seed {seed:#x})");
    println!(
        "  {} GPCs enabled, {} TPCs, {} SMs, {} memory resource groups (half-GPCs)",
        cfg.topology.enabled_gpcs,
        cfg.topology.enabled_tpcs,
        topo.sm_count(),
        topo.group_count()
    );
    println!(
        "  group sizes: {:?}",
        (0..topo.group_count())
            .map(|g| topo.group_sizes()[g])
            .collect::<Vec<_>>()
    );
    println!(
        "  per-group TLB: {} x {} KiB pages = {} GiB reach, {}-way LRU, {} walkers @ {} ns",
        cfg.tlb.entries,
        cfg.tlb.page_bytes / 1024,
        cfg.tlb.reach_bytes() / (1 << 30),
        cfg.tlb.associativity,
        cfg.tlb.walkers_per_group,
        cfg.tlb.walk_ns
    );
    println!(
        "  HBM: {} GiB, {} channels, {:.0} GB/s peak ({:.0} effective for 128 B random)",
        cfg.memory.total_bytes / (1 << 30),
        cfg.memory.channels,
        cfg.memory.peak_gbps,
        cfg.memory.peak_gbps * cfg.memory.efficiency_128b
    );
    println!();
    println!("the paper's technique on this card:");
    println!(
        "  random access over all {} GiB thrashes every group's TLB (reach {} GiB);",
        cfg.memory.total_bytes / (1 << 30),
        cfg.tlb.reach_bytes() / (1 << 30)
    );
    println!("  probe the pair matrix (fig 2-3) to discover the groups, then pin each");
    println!("  group to a window smaller than reach (fig 6) to restore full speed.");
    println!("  run `a100win probe` then `a100win fig 6` to see it.");
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(argv: &[&str]) -> Args {
        Args::parse(&argv.iter().map(|s| s.to_string()).collect::<Vec<_>>()).unwrap()
    }

    #[test]
    fn args_positional_and_flags() {
        let a = parse(&["6", "--seed", "42", "--effort", "full"]);
        assert_eq!(a.positional, vec!["6"]);
        assert_eq!(a.flag("seed"), Some("42"));
        assert_eq!(a.u64_flag("seed", 0).unwrap(), 42);
        assert!(matches!(a.effort().unwrap(), Effort::Full));
    }

    #[test]
    fn args_defaults() {
        let a = parse(&[]);
        assert_eq!(a.u64_flag("seed", 7).unwrap(), 7);
        assert!(a.flag("none").is_none());
    }

    #[test]
    fn args_rejects_bad_numbers() {
        // A bare value-flag parses as boolean (empty value) and fails the
        // typed accessor instead of failing parse.
        let a = parse(&["--seed"]);
        assert!(a.u64_flag("seed", 0).is_err());
        let a = parse(&["--seed", "abc"]);
        assert!(a.u64_flag("seed", 0).is_err());
        let a = parse(&["--effort", "bogus"]);
        assert!(a.effort().is_err());
    }

    #[test]
    fn args_boolean_flags() {
        let a = parse(&["--chaos", "--seed", "7", "--verify", "64"]);
        assert!(a.bool_flag("chaos"));
        assert!(!a.bool_flag("nope"));
        assert_eq!(a.u64_flag("seed", 0).unwrap(), 7);
        assert_eq!(a.u64_flag("verify", 0).unwrap(), 64);
    }

    #[test]
    fn unknown_subcommand_errors() {
        assert!(run(&["bogus".to_string()]).is_err());
    }

    fn run_str(argv: &[&str]) -> anyhow::Result<()> {
        run(&argv.iter().map(|s| s.to_string()).collect::<Vec<_>>())
    }

    #[test]
    fn unknown_flag_rejected_not_ignored() {
        // The typo'd chaos gate: --choas must error, not silently run the
        // plain benchmark.
        let err = run_str(&["bench-serve", "--choas"]).unwrap_err();
        let msg = format!("{err:#}");
        assert!(msg.contains("unknown flag --choas"), "got: {msg}");
        assert!(msg.contains("bench-serve"), "got: {msg}");
    }

    #[test]
    fn unknown_flag_reports_first_alphabetically() {
        // Deterministic error regardless of HashMap iteration order.
        let a = parse(&["--zzz", "1", "--aaa", "2"]);
        let err = a.reject_unknown("probe", &["seed"]).unwrap_err();
        assert!(format!("{err:#}").contains("--aaa"), "got: {err:#}");
    }

    #[test]
    fn known_flags_pass_rejection() {
        let a = parse(&["--seed", "42", "--out", "x.json", "--effort", "quick"]);
        a.reject_unknown("probe", allowed_flags("probe")).unwrap();
        // Every flag named in USAGE for bench-serve is in its vocabulary.
        for f in ["chaos", "remote", "conns", "deadline-ms", "verify"] {
            assert!(
                allowed_flags("bench-serve").contains(&f),
                "bench-serve vocabulary is missing --{f}"
            );
        }
        for f in ["port", "http-port", "selfcheck", "drain-ms"] {
            assert!(
                allowed_flags("serve-net").contains(&f),
                "serve-net vocabulary is missing --{f}"
            );
        }
    }
}

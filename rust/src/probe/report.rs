//! The probe's output artifact: a card-specific `TopologyMap` that the
//! coordinator consumes to place windows.
//!
//! Serialized as JSON (via the in-tree [`crate::util::json`] substrate) so
//! a probe run on one process can feed coordinators in another — mirroring
//! how the paper's technique would ship: probe once per card at install
//! time, then reuse the map.

use std::path::Path;

use anyhow::{anyhow, Context};

use crate::sim::{Machine, SmId};
use crate::util::json::Json;

/// Calibrated solo throughput of one SM in thrash-free steady state, GB/s
/// (engine calibration: 48 outstanding × 128 B / ~390 ns ≈ 15 GB/s; paper
/// Fig 4 shows ~120 GB/s for an 8-SM group).  Used to synthesize the
/// ground-truth map's `solo_gbps` without running the probe.
pub const SOLO_GBPS_PER_SM: f64 = 15.0;

/// What the probe learned about a card.
#[derive(Debug, Clone, PartialEq)]
pub struct TopologyMap {
    /// Discovered resource groups (each a set of smids).
    pub groups: Vec<Vec<SmId>>,
    /// Estimated per-group TLB reach in bytes (from the region sweep; the
    /// A100 answer is 64 GiB).
    pub reach_bytes: u64,
    /// Solo throughput per group, GB/s (Fig-4 data; used by the
    /// coordinator to weight window sizes).
    pub solo_gbps: Vec<f64>,
    /// Did the independence check (Fig 5) pass?
    pub independent: bool,
    /// Seed / identity of the probed card.
    pub card_id: String,
}

impl TopologyMap {
    /// The map a perfect probe of `machine` would produce, read straight
    /// from the simulator's ground truth.  Used where the experiment (or
    /// server) is about *placement*, not discovery — a real deployment
    /// would load `a100win probe`'s output, which carries identical
    /// content on a correctly probed card.
    pub fn ground_truth(machine: &Machine) -> Self {
        let topo = machine.topology();
        Self {
            groups: topo.sm_groups(),
            reach_bytes: machine.config().tlb.reach_bytes(),
            solo_gbps: topo
                .group_sizes()
                .iter()
                .map(|&s| s as f64 * SOLO_GBPS_PER_SM)
                .collect(),
            independent: true,
            card_id: format!(
                "ground-truth-{:#x}",
                machine.config().topology.smid_permutation_seed
            ),
        }
    }

    /// Group id for an smid, if the map covers it.
    pub fn group_of(&self, smid: SmId) -> Option<usize> {
        self.groups.iter().position(|g| g.contains(&smid))
    }

    pub fn sm_count(&self) -> usize {
        self.groups.iter().map(|g| g.len()).sum()
    }

    /// Sanity-check structural invariants.
    pub fn validate(&self) -> anyhow::Result<()> {
        if self.groups.is_empty() {
            return Err(anyhow!("no groups"));
        }
        if self.groups.len() != self.solo_gbps.len() {
            return Err(anyhow!("solo_gbps length mismatch"));
        }
        let mut seen = std::collections::HashSet::new();
        for g in &self.groups {
            if g.is_empty() {
                return Err(anyhow!("empty group"));
            }
            for &sm in g {
                if !seen.insert(sm) {
                    return Err(anyhow!("smid {sm} appears twice"));
                }
            }
        }
        if self.reach_bytes == 0 {
            return Err(anyhow!("reach_bytes is zero"));
        }
        Ok(())
    }

    // ---- JSON round-trip -----------------------------------------------

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("card_id", Json::str(self.card_id.clone())),
            ("reach_bytes", Json::num(self.reach_bytes as f64)),
            ("independent", Json::Bool(self.independent)),
            (
                "groups",
                Json::arr(
                    self.groups
                        .iter()
                        .map(|g| Json::arr(g.iter().map(|&s| Json::num(s as f64)).collect()))
                        .collect(),
                ),
            ),
            (
                "solo_gbps",
                Json::arr(self.solo_gbps.iter().map(|&x| Json::num(x)).collect()),
            ),
        ])
    }

    pub fn from_json(v: &Json) -> anyhow::Result<Self> {
        let groups = v
            .get("groups")
            .and_then(|g| g.as_arr())
            .ok_or_else(|| anyhow!("missing groups"))?
            .iter()
            .map(|g| {
                g.as_arr()
                    .ok_or_else(|| anyhow!("group not an array"))?
                    .iter()
                    .map(|s| s.as_usize().ok_or_else(|| anyhow!("bad smid")))
                    .collect::<anyhow::Result<Vec<_>>>()
            })
            .collect::<anyhow::Result<Vec<_>>>()?;
        let solo_gbps = v
            .get("solo_gbps")
            .and_then(|g| g.as_arr())
            .ok_or_else(|| anyhow!("missing solo_gbps"))?
            .iter()
            .map(|x| x.as_f64().ok_or_else(|| anyhow!("bad solo_gbps")))
            .collect::<anyhow::Result<Vec<_>>>()?;
        let map = Self {
            groups,
            reach_bytes: v
                .get("reach_bytes")
                .and_then(|x| x.as_u64())
                .ok_or_else(|| anyhow!("missing reach_bytes"))?,
            independent: v
                .get("independent")
                .and_then(|x| x.as_bool())
                .unwrap_or(false),
            solo_gbps,
            card_id: v
                .get("card_id")
                .and_then(|x| x.as_str())
                .unwrap_or("unknown")
                .to_string(),
        };
        map.validate()?;
        Ok(map)
    }

    pub fn save(&self, path: &Path) -> anyhow::Result<()> {
        std::fs::write(path, self.to_json().to_string_pretty())
            .with_context(|| format!("writing {}", path.display()))
    }

    pub fn load(path: &Path) -> anyhow::Result<Self> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading {}", path.display()))?;
        Self::from_json(&Json::parse(&text)?)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> TopologyMap {
        TopologyMap {
            groups: vec![vec![0, 1, 4, 5], vec![2, 3, 6, 7]],
            reach_bytes: 64 << 30,
            solo_gbps: vec![120.0, 118.5],
            independent: true,
            card_id: "sim-a100-seed-0xA100".into(),
        }
    }

    #[test]
    fn validate_accepts_sample() {
        sample().validate().unwrap();
    }

    #[test]
    fn validate_rejects_duplicates_and_empties() {
        let mut m = sample();
        m.groups[1][0] = 0; // duplicate smid
        assert!(m.validate().is_err());

        let mut m = sample();
        m.groups.push(vec![]);
        m.solo_gbps.push(0.0);
        assert!(m.validate().is_err());

        let mut m = sample();
        m.solo_gbps.pop();
        assert!(m.validate().is_err());

        let mut m = sample();
        m.reach_bytes = 0;
        assert!(m.validate().is_err());
    }

    #[test]
    fn json_roundtrip() {
        let m = sample();
        let back = TopologyMap::from_json(&m.to_json()).unwrap();
        assert_eq!(m, back);
    }

    #[test]
    fn file_roundtrip() {
        let m = sample();
        let dir = std::env::temp_dir().join(format!("a100win-test-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("topomap.json");
        m.save(&path).unwrap();
        let back = TopologyMap::load(&path).unwrap();
        assert_eq!(m, back);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn group_of_lookup() {
        let m = sample();
        assert_eq!(m.group_of(5), Some(0));
        assert_eq!(m.group_of(3), Some(1));
        assert_eq!(m.group_of(99), None);
        assert_eq!(m.sm_count(), 8);
    }

    #[test]
    fn ground_truth_matches_machine_topology() {
        let machine = Machine::new(crate::config::MachineConfig::tiny_test()).unwrap();
        let map = TopologyMap::ground_truth(&machine);
        map.validate().unwrap();
        let topo = machine.topology();
        assert_eq!(map.groups.len(), topo.group_count());
        assert_eq!(map.sm_count(), topo.sm_count());
        assert_eq!(map.reach_bytes, machine.config().tlb.reach_bytes());
        for (g, sms) in map.groups.iter().enumerate() {
            for &sm in sms {
                assert_eq!(topo.group_of(sm), g);
            }
            assert_eq!(map.solo_gbps[g], sms.len() as f64 * SOLO_GBPS_PER_SM);
        }
        assert!(map.independent);
    }
}

//! Pair probing (paper §2.2, Fig 2): measure throughput for every pair of
//! SMs and look for the contention fingerprint of shared resources.
//!
//! The probe points the benchmark at a region *larger than any plausible
//! TLB reach* so that translation — not data bandwidth — is the bottleneck.
//! Two SMs that share translation hardware (TLB + page walkers) then
//! collapse to roughly half the throughput of two SMs that do not.  With a
//! TLB-resident region the signal would vanish: two SMs pull ~30 GB/s,
//! nowhere near any shared port's bandwidth.  (The paper does not spell out
//! its probe region size; thrash mode is the regime in which its Fig-2
//! pattern is strongest.)

use crate::sim::{Machine, MeasurementSpec, MemRegion, Pattern, SmId};
use crate::util::threads::default_workers;

/// Configuration for the pair sweep.
#[derive(Debug, Clone)]
pub struct PairProbeConfig {
    /// Region each probe run reads (default: the whole device, which
    /// exceeds the 64 GB reach and forces translation pressure).
    pub region: MemRegion,
    /// Accesses per SM per run.  Small: only the *relative* throughput of
    /// pairs matters.
    pub accesses_per_sm: u64,
    pub seed: u64,
    /// OS threads for the sweep (runs are independent simulations).
    pub workers: usize,
}

impl PairProbeConfig {
    pub fn for_machine(m: &Machine) -> Self {
        Self {
            region: MemRegion::whole(m.config().memory.total_bytes),
            accesses_per_sm: 3_000,
            seed: 0xFA15,
            workers: default_workers(),
        }
    }
}

/// The symmetric pair-throughput matrix (GB/s), `sm_count x sm_count`.
/// Diagonal holds each SM's solo throughput.
#[derive(Debug, Clone)]
pub struct PairMatrix {
    pub n: usize,
    data: Vec<f64>,
}

impl PairMatrix {
    fn new(n: usize) -> Self {
        Self {
            n,
            data: vec![0.0; n * n],
        }
    }

    #[inline]
    pub fn get(&self, i: SmId, j: SmId) -> f64 {
        self.data[i * self.n + j]
    }

    fn set(&mut self, i: SmId, j: SmId, v: f64) {
        self.data[i * self.n + j] = v;
        self.data[j * self.n + i] = v;
    }

    /// Mean off-diagonal throughput (normalization reference).
    pub fn mean_offdiag(&self) -> f64 {
        let mut sum = 0.0;
        let mut cnt = 0usize;
        for i in 0..self.n {
            for j in 0..self.n {
                if i != j {
                    sum += self.get(i, j);
                    cnt += 1;
                }
            }
        }
        sum / cnt as f64
    }

    /// Render the matrix with a permutation applied to both axes (Fig 3's
    /// "rearranged indices" view).  `shade` maps a throughput to a glyph.
    pub fn render(&self, perm: &[SmId]) -> String {
        assert_eq!(perm.len(), self.n);
        let mean = self.mean_offdiag();
        let mut out = String::with_capacity(self.n * (self.n + 1));
        for &i in perm {
            for &j in perm {
                let c = if i == j {
                    '@'
                } else {
                    let ratio = self.get(i, j) / mean;
                    if ratio < 0.75 {
                        '#' // strong contention: shared group
                    } else if ratio < 0.97 {
                        '+' // faint contention: shared GPC hub
                    } else {
                        '.'
                    }
                };
                out.push(c);
            }
            out.push('\n');
        }
        out
    }

    /// CSV of the (optionally permuted) matrix.
    pub fn to_csv(&self, perm: &[SmId]) -> String {
        let mut s = String::new();
        for &i in perm {
            let row: Vec<String> = perm.iter().map(|&j| format!("{:.2}", self.get(i, j))).collect();
            s.push_str(&row.join(","));
            s.push('\n');
        }
        s
    }
}

/// Run the full pair sweep: `n*(n-1)/2` two-SM runs plus `n` solo runs.
pub fn pair_probe(machine: &Machine, cfg: &PairProbeConfig) -> PairMatrix {
    let n = machine.topology().sm_count();
    let mut jobs: Vec<(SmId, SmId)> = Vec::with_capacity(n * (n + 1) / 2);
    for i in 0..n {
        for j in i..n {
            jobs.push((i, j));
        }
    }
    let specs: Vec<MeasurementSpec> = jobs
        .iter()
        .map(|&(i, j)| {
            let sms: Vec<SmId> = if i == j { vec![i] } else { vec![i, j] };
            MeasurementSpec::uniform_all(
                &sms,
                Pattern::Uniform(cfg.region),
                cfg.accesses_per_sm,
                cfg.seed ^ ((i as u64) << 32 | j as u64),
            )
        })
        .collect();
    let results = machine.run_many_with(&specs, cfg.workers);
    let mut m = PairMatrix::new(n);
    for ((i, j), meas) in jobs.into_iter().zip(results) {
        m.set(i, j, meas.gbps);
    }
    m
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::MachineConfig;

    fn tiny_machine() -> Machine {
        Machine::new(MachineConfig::tiny_test()).unwrap()
    }

    fn tiny_probe(m: &Machine) -> PairMatrix {
        let mut cfg = PairProbeConfig::for_machine(m);
        cfg.accesses_per_sm = 2_000;
        cfg.workers = 4;
        pair_probe(m, &cfg)
    }

    #[test]
    fn matrix_is_symmetric_and_positive() {
        let m = tiny_machine();
        let pm = tiny_probe(&m);
        assert_eq!(pm.n, 12);
        for i in 0..pm.n {
            assert!(pm.get(i, i) > 0.0);
            for j in 0..pm.n {
                assert_eq!(pm.get(i, j), pm.get(j, i));
            }
        }
    }

    #[test]
    fn same_group_pairs_are_slower() {
        let m = tiny_machine();
        let pm = tiny_probe(&m);
        let topo = m.topology();
        let (mut same_sum, mut same_n, mut diff_sum, mut diff_n) = (0.0, 0, 0.0, 0);
        for i in 0..pm.n {
            for j in (i + 1)..pm.n {
                if topo.group_of(i) == topo.group_of(j) {
                    same_sum += pm.get(i, j);
                    same_n += 1;
                } else {
                    diff_sum += pm.get(i, j);
                    diff_n += 1;
                }
            }
        }
        let same = same_sum / same_n as f64;
        let diff = diff_sum / diff_n as f64;
        assert!(
            diff / same > 1.5,
            "expected strong group signal: same={same:.2} diff={diff:.2}"
        );
    }

    #[test]
    fn render_shows_group_blocks() {
        let m = tiny_machine();
        let pm = tiny_probe(&m);
        // Group-sorted permutation must produce '#' marks for group mates.
        let topo = m.topology();
        let mut perm: Vec<usize> = (0..pm.n).collect();
        perm.sort_by_key(|&s| topo.group_of(s));
        let s = pm.render(&perm);
        assert_eq!(s.lines().count(), pm.n);
        assert!(s.contains('#'));
        assert!(s.contains('.'));
        assert!(s.contains('@'));
    }

    #[test]
    fn csv_has_n_rows() {
        let m = tiny_machine();
        let pm = tiny_probe(&m);
        let perm: Vec<usize> = (0..pm.n).collect();
        let csv = pm.to_csv(&perm);
        assert_eq!(csv.lines().count(), pm.n);
        assert_eq!(csv.lines().next().unwrap().split(',').count(), pm.n);
    }

    #[test]
    fn probe_is_deterministic() {
        let m = tiny_machine();
        let a = tiny_probe(&m);
        let b = tiny_probe(&m);
        assert_eq!(a.data, b.data);
    }
}

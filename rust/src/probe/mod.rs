//! The paper's reverse-engineering technique, end to end.
//!
//! [`Prober`] chains the pieces:
//!
//! 1. [`pair`]    — Fig 2: throughput matrix over all SM pairs.
//! 2. [`cluster`] — Fig 3: rearrangement / connected components -> groups.
//! 3. [`verify`]  — Figs 4–5: solo-group scaling + pairwise independence.
//! 4. reach sweep —  Fig 1 mechanism: grow one group's region until
//!    throughput collapses; the knee is the per-group TLB reach.
//! 5. [`report`]  — the `TopologyMap` artifact the coordinator consumes.
//!
//! Everything here treats the [`Machine`](crate::sim::Machine) as an opaque
//! device: only smid lists go in, only throughput comes out.  Ground-truth
//! topology is never consulted (tests check the *discovered* map against
//! it, the prober itself cannot).

pub mod cluster;
pub mod pair;
pub mod report;
pub mod verify;

use crate::sim::{Machine, MeasurementSpec, MemRegion, Pattern};

pub use cluster::{cluster, Clustering};
pub use pair::{pair_probe, PairMatrix, PairProbeConfig};
pub use report::TopologyMap;
pub use verify::{group_pairs, solo_groups, GroupPairResult, SoloGroupResult, VerifyConfig};

/// Tunables for a full probe run.
#[derive(Debug, Clone)]
pub struct ProbeConfig {
    pub pair: PairProbeConfig,
    pub verify: VerifyConfig,
    /// Region sizes (bytes) for the reach sweep.  Default: 12 points from
    /// 1/12 of memory to all of it.
    pub reach_sweep: Vec<u64>,
    /// Relative throughput drop that marks the reach knee.
    pub knee_ratio: f64,
    /// Tolerance for the independence verdict.
    pub independence_tolerance: f64,
}

impl ProbeConfig {
    pub fn for_machine(m: &Machine) -> Self {
        let total = m.config().memory.total_bytes;
        let page = m.config().tlb.page_bytes;
        let mut sweep = Vec::new();
        for k in 1..=12u64 {
            let bytes = total * k / 12;
            sweep.push((bytes / page).max(1) * page);
        }
        Self {
            pair: PairProbeConfig::for_machine(m),
            verify: VerifyConfig::for_machine(m),
            reach_sweep: sweep,
            knee_ratio: 0.7,
            independence_tolerance: 0.15,
        }
    }
}

/// Full probe outcome (the map plus the raw evidence behind it).
#[derive(Debug, Clone)]
pub struct ProbeOutcome {
    pub map: TopologyMap,
    pub matrix: PairMatrix,
    pub clustering: Clustering,
    pub solos: Vec<SoloGroupResult>,
    pub pairs: Vec<GroupPairResult>,
    /// (region_bytes, gbps) points of the reach sweep.
    pub reach_curve: Vec<(u64, f64)>,
}

/// High-level driver for the probe pipeline.
pub struct Prober<'m> {
    machine: &'m Machine,
    cfg: ProbeConfig,
}

impl<'m> Prober<'m> {
    pub fn new(machine: &'m Machine) -> Self {
        let cfg = ProbeConfig::for_machine(machine);
        Self { machine, cfg }
    }

    pub fn with_config(machine: &'m Machine, cfg: ProbeConfig) -> Self {
        Self { machine, cfg }
    }

    pub fn config(&self) -> &ProbeConfig {
        &self.cfg
    }

    /// Estimate one group's TLB reach: sweep region sizes, find the knee
    /// where throughput falls below `knee_ratio` x the small-region value.
    /// Returns (reach estimate, curve).
    pub fn reach_sweep(&self, group: &[crate::sim::SmId]) -> (u64, Vec<(u64, f64)>) {
        let per_sm = self.cfg.verify.accesses_per_sm;
        let seed = self.cfg.verify.seed;
        let specs: Vec<MeasurementSpec> = self
            .cfg
            .reach_sweep
            .iter()
            .map(|&bytes| {
                MeasurementSpec::uniform_all(
                    group,
                    Pattern::Uniform(MemRegion::new(0, bytes)),
                    per_sm,
                    seed ^ bytes,
                )
            })
            .collect();
        let curve: Vec<(u64, f64)> = self
            .cfg
            .reach_sweep
            .iter()
            .zip(self.machine.run_many(&specs))
            .map(|(&bytes, meas)| (bytes, meas.gbps))
            .collect();
        let baseline = curve
            .iter()
            .take(3)
            .map(|&(_, g)| g)
            .fold(0.0f64, f64::max);
        // The knee is the first region size whose throughput falls below
        // the threshold; the conservative reach estimate is the sweep point
        // before it.
        let mut est = curve.last().map(|&(b, _)| b).unwrap_or(0);
        for (idx, &(bytes, gbps)) in curve.iter().enumerate() {
            if gbps < baseline * self.cfg.knee_ratio {
                est = if idx > 0 { curve[idx - 1].0 } else { bytes };
                break;
            }
        }
        (est, curve)
    }

    /// Run the whole pipeline.
    pub fn run(&self) -> anyhow::Result<ProbeOutcome> {
        let matrix = pair_probe(self.machine, &self.cfg.pair);
        let mut clustering = cluster(&matrix);
        // No contention signal?  That happens when the card's entire memory
        // fits under every TLB's reach (e.g. the 40 GB variant): the thrash
        // probe never thrashes, pair throughputs are unimodal, and any
        // partition would be noise.  Report one undivided group — placement
        // is irrelevant on such a card, and the map stays honest.
        if clustering.contrast < 1.2 {
            let n = self.machine.topology().sm_count();
            clustering.groups = vec![(0..n).collect()];
            clustering.group_of = vec![0; n];
            clustering.permutation = (0..n).collect();
        }
        let solos = solo_groups(self.machine, &clustering.groups, &self.cfg.verify);
        // All-pairs verification is O(groups^2) runs — cheap next to the
        // O(sms^2) pair sweep.
        let pairs = group_pairs(
            self.machine,
            &clustering.groups,
            &solos,
            None,
            &self.cfg.verify,
        );
        let independent = verify::groups_independent(&pairs, self.cfg.independence_tolerance);
        // Reach: sweep the largest discovered group (most demand pressure).
        let largest = clustering
            .groups
            .iter()
            .enumerate()
            .max_by_key(|(_, g)| g.len())
            .map(|(i, _)| i)
            .unwrap();
        let (reach_bytes, reach_curve) = self.reach_sweep(&clustering.groups[largest]);

        let map = TopologyMap {
            groups: clustering.groups.clone(),
            reach_bytes,
            solo_gbps: solos.iter().map(|s| s.gbps).collect(),
            independent,
            card_id: format!(
                "sim-seed-{:#x}",
                self.machine.config().topology.smid_permutation_seed
            ),
        };
        map.validate()?;
        Ok(ProbeOutcome {
            map,
            matrix,
            clustering,
            solos,
            pairs,
            reach_curve,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::MachineConfig;

    #[test]
    fn full_pipeline_on_tiny_machine() {
        let m = Machine::new(MachineConfig::tiny_test()).unwrap();
        let mut cfg = ProbeConfig::for_machine(&m);
        cfg.pair.accesses_per_sm = 2_000;
        cfg.verify.accesses_per_sm = 3_000;
        let outcome = Prober::with_config(&m, cfg).run().unwrap();

        // Discovered structure matches ground truth.
        let topo = m.topology();
        assert_eq!(outcome.map.groups.len(), topo.group_count());
        assert_eq!(outcome.map.sm_count(), topo.sm_count());
        for g in &outcome.map.groups {
            let want = topo.group_of(g[0]);
            assert!(g.iter().all(|&s| topo.group_of(s) == want));
        }

        // Independence held, and the reach estimate brackets the true reach.
        assert!(outcome.map.independent);
        let true_reach = m.config().tlb.reach_bytes(); // 16 MiB on tiny
        assert!(
            outcome.map.reach_bytes >= true_reach / 2
                && outcome.map.reach_bytes <= true_reach * 2,
            "reach estimate {} vs true {true_reach}",
            outcome.map.reach_bytes
        );
        // The sweep must actually show the cliff: max/min ratio is large.
        let max = outcome
            .reach_curve
            .iter()
            .map(|&(_, g)| g)
            .fold(0.0, f64::max);
        let min = outcome
            .reach_curve
            .iter()
            .map(|&(_, g)| g)
            .fold(f64::INFINITY, f64::min);
        assert!(max / min > 2.0, "no cliff in reach curve: {max} / {min}");
    }

    #[test]
    fn reach_sweep_monotone_regions() {
        let m = Machine::new(MachineConfig::tiny_test()).unwrap();
        let cfg = ProbeConfig::for_machine(&m);
        assert!(cfg.reach_sweep.windows(2).all(|w| w[0] <= w[1]));
        let page = m.config().tlb.page_bytes;
        assert!(cfg.reach_sweep.iter().all(|&b| b % page == 0 && b > 0));
    }
}

//! Verification experiments (paper §2.3, Figs 4–5): confirm the discovered
//! groups behave like independent translation domains.
//!
//! * `solo_groups` — run each discovered group alone over a TLB-resident
//!   region; throughput must scale with member count (Fig 4: ~120 GB/s for
//!   8-SM groups vs ~90 for 6-SM, ratio 8/6).
//! * `group_pairs` — run pairs of groups, each over a *disjoint* region; if
//!   the pair achieves ~2x a solo group, the groups do not share a TLB
//!   (Fig 5), so per-group windows are enough to dodge translation limits.

use crate::sim::{Machine, MeasurementSpec, MemRegion, Pattern, SmAssignment, SmId};
use crate::util::threads::default_workers;

/// One solo-group measurement (Fig 4 bar).
#[derive(Debug, Clone)]
pub struct SoloGroupResult {
    pub group_index: usize,
    pub sm_count: usize,
    pub gbps: f64,
}

/// One group-pair measurement (Fig 5 point).
#[derive(Debug, Clone)]
pub struct GroupPairResult {
    pub a: usize,
    pub b: usize,
    pub gbps: f64,
    /// Sum of the two solo throughputs (the "independent" prediction).
    pub solo_sum: f64,
}

/// Shared parameters for verification runs.
#[derive(Debug, Clone)]
pub struct VerifyConfig {
    /// Region size per group (must be well under TLB reach; the paper uses
    /// 40 GB).
    pub region_bytes: u64,
    pub accesses_per_sm: u64,
    pub seed: u64,
    pub workers: usize,
}

impl VerifyConfig {
    pub fn for_machine(m: &Machine) -> Self {
        Self {
            region_bytes: (m.config().memory.total_bytes / 2)
                .min(m.config().tlb.reach_bytes() / 2),
            accesses_per_sm: 6_000,
            seed: 0xF16,
            workers: default_workers(),
        }
    }
}

/// Fig 4: each discovered group alone.
pub fn solo_groups(
    machine: &Machine,
    groups: &[Vec<SmId>],
    cfg: &VerifyConfig,
) -> Vec<SoloGroupResult> {
    let region = MemRegion::new(0, cfg.region_bytes);
    let specs: Vec<MeasurementSpec> = (0..groups.len())
        .map(|gi| {
            MeasurementSpec::uniform_all(
                &groups[gi],
                Pattern::Uniform(region),
                cfg.accesses_per_sm,
                cfg.seed ^ gi as u64,
            )
        })
        .collect();
    machine
        .run_many_with(&specs, cfg.workers)
        .into_iter()
        .enumerate()
        .map(|(gi, meas)| SoloGroupResult {
            group_index: gi,
            sm_count: groups[gi].len(),
            gbps: meas.gbps,
        })
        .collect()
}

/// Fig 5: pairs of groups over disjoint regions.  `pairs` defaults to all
/// C(n,2) pairs when `None` (the paper plots all pairs).
pub fn group_pairs(
    machine: &Machine,
    groups: &[Vec<SmId>],
    solos: &[SoloGroupResult],
    pairs: Option<Vec<(usize, usize)>>,
    cfg: &VerifyConfig,
) -> Vec<GroupPairResult> {
    let jobs: Vec<(usize, usize)> = pairs.unwrap_or_else(|| {
        let mut v = Vec::new();
        for a in 0..groups.len() {
            for b in (a + 1)..groups.len() {
                v.push((a, b));
            }
        }
        v
    });
    let r1 = MemRegion::new(0, cfg.region_bytes);
    let r2 = MemRegion::new(cfg.region_bytes, cfg.region_bytes);
    let specs: Vec<MeasurementSpec> = jobs
        .iter()
        .map(|&(a, b)| {
            let mut assignments: Vec<SmAssignment> = Vec::new();
            for &smid in &groups[a] {
                assignments.push(SmAssignment {
                    smid,
                    pattern: Pattern::Uniform(r1),
                });
            }
            for &smid in &groups[b] {
                assignments.push(SmAssignment {
                    smid,
                    pattern: Pattern::Uniform(r2),
                });
            }
            MeasurementSpec {
                assignments,
                accesses_per_sm: cfg.accesses_per_sm,
                warmup_fraction: 0.25,
                txn_bytes: crate::config::LINE_BYTES,
                seed: cfg.seed ^ ((a as u64) << 32 | b as u64),
            }
        })
        .collect();
    let results = machine.run_many_with(&specs, cfg.workers);
    jobs.into_iter()
        .zip(results)
        .map(|((a, b), meas)| GroupPairResult {
            a,
            b,
            gbps: meas.gbps,
            solo_sum: solos[a].gbps + solos[b].gbps,
        })
        .collect()
}

/// Independence verdict over the pair results: true when every pair lands
/// within `tolerance` of its solo-sum prediction (paper: "almost exactly
/// double").
pub fn groups_independent(pairs: &[GroupPairResult], tolerance: f64) -> bool {
    pairs
        .iter()
        .all(|p| (p.gbps / p.solo_sum - 1.0).abs() <= tolerance)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::MachineConfig;

    fn setup() -> (Machine, Vec<Vec<SmId>>, VerifyConfig) {
        let m = Machine::new(MachineConfig::tiny_test()).unwrap();
        // Verification is about group behavior, not discovery: read the
        // partition from the ground-truth map.
        let groups = crate::probe::TopologyMap::ground_truth(&m).groups;
        let mut cfg = VerifyConfig::for_machine(&m);
        cfg.accesses_per_sm = 3_000;
        cfg.workers = 4;
        (m, groups, cfg)
    }

    #[test]
    fn verify_region_fits_under_reach() {
        let (m, _g, cfg) = setup();
        assert!(cfg.region_bytes <= m.config().tlb.reach_bytes());
        assert!(2 * cfg.region_bytes <= m.config().memory.total_bytes);
    }

    #[test]
    fn solo_scales_with_sm_count() {
        let (m, groups, cfg) = setup();
        let solos = solo_groups(&m, &groups, &cfg);
        assert_eq!(solos.len(), groups.len());
        for s in &solos {
            let per_sm = s.gbps / s.sm_count as f64;
            assert!(
                per_sm > 10.0 && per_sm < 20.0,
                "group {}: {per_sm:.1} GB/s per SM",
                s.group_index
            );
        }
    }

    #[test]
    fn pairs_double_solo() {
        let (m, groups, cfg) = setup();
        let solos = solo_groups(&m, &groups, &cfg);
        let pairs = group_pairs(&m, &groups, &solos, Some(vec![(0, 1), (1, 2), (0, 3)]), &cfg);
        assert!(groups_independent(&pairs, 0.15), "{pairs:?}");
    }

    #[test]
    fn all_pairs_cover_upper_triangle() {
        let (m, groups, cfg) = setup();
        let solos = solo_groups(&m, &groups, &cfg);
        let pairs = group_pairs(&m, &groups, &solos, None, &cfg);
        let n = groups.len();
        assert_eq!(pairs.len(), n * (n - 1) / 2);
    }
}

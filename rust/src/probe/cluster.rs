//! Clustering the pair matrix into resource groups (paper §2.2, Fig 3).
//!
//! The paper "rearranges the indices" of the Fig-2 matrix until the shared
//! groups appear as blocks.  Algorithmically that is: threshold the matrix
//! into a "shares resources" relation, then take connected components —
//! SMs in one half-GPC all contend with each other through the same TLB /
//! walker pool, so the relation is (noisily) transitive and components
//! recover the groups.  The permutation that sorts SMs by discovered
//! component is exactly the paper's Fig-3 rearrangement.

use crate::probe::pair::PairMatrix;
use crate::sim::SmId;

/// Result of clustering.
#[derive(Debug, Clone)]
pub struct Clustering {
    /// Discovered group id per smid (dense, 0-based, ordered by first
    /// member smid).
    pub group_of: Vec<usize>,
    /// Members per discovered group.
    pub groups: Vec<Vec<SmId>>,
    /// Permutation of smids sorted by (group, smid) — the Fig-3 view.
    pub permutation: Vec<SmId>,
    /// The contention threshold used (fraction of mean off-diagonal).
    pub threshold: f64,
    /// Bimodality contrast: mean(pairs above threshold) / mean(below).
    /// ~1.0 means the matrix carries no contention signal (e.g. a card
    /// whose whole memory fits under TLB reach); >1.3 is a clean split.
    pub contrast: f64,
}

/// Union-find over smids.
struct Dsu {
    parent: Vec<usize>,
}

impl Dsu {
    fn new(n: usize) -> Self {
        Self {
            parent: (0..n).collect(),
        }
    }

    fn find(&mut self, x: usize) -> usize {
        if self.parent[x] != x {
            let root = self.find(self.parent[x]);
            self.parent[x] = root;
        }
        self.parent[x]
    }

    fn union(&mut self, a: usize, b: usize) {
        let (ra, rb) = (self.find(a), self.find(b));
        if ra != rb {
            self.parent[ra.max(rb)] = ra.min(rb);
        }
    }
}

/// Pick the contention threshold from the matrix itself: the pair
/// throughputs are bimodal (contended ~half of uncontended), so the largest
/// gap in the sorted off-diagonal values separates the modes.
pub fn auto_threshold(m: &PairMatrix) -> f64 {
    let mut vals: Vec<f64> = Vec::with_capacity(m.n * (m.n - 1) / 2);
    for i in 0..m.n {
        for j in (i + 1)..m.n {
            vals.push(m.get(i, j));
        }
    }
    vals.sort_by(|a, b| a.partial_cmp(b).unwrap());
    // Find the widest relative gap in the middle 90% of the distribution.
    let lo = vals.len() / 20;
    let hi = vals.len() - vals.len() / 20;
    let mut best_gap = 0.0;
    let mut best_mid = vals[vals.len() / 2];
    for k in lo..hi.saturating_sub(1) {
        let gap = vals[k + 1] - vals[k];
        if gap > best_gap {
            best_gap = gap;
            best_mid = (vals[k + 1] + vals[k]) / 2.0;
        }
    }
    best_mid
}

/// Cluster the matrix into resource groups.
///
/// Robustness: raw single-link union-find chains through any single noisy
/// pair, merging whole groups.  So an edge (i, j) below threshold only
/// counts when i and j also *agree about everyone else*: their dark-
/// neighbor sets must overlap substantially (Jaccard >= 0.5).  True group
/// mates contend with the identical SM set; a one-off outlier pair shares
/// almost none.
pub fn cluster(m: &PairMatrix) -> Clustering {
    let thr = auto_threshold(m);
    let dark: Vec<Vec<bool>> = (0..m.n)
        .map(|i| {
            (0..m.n)
                .map(|j| i != j && m.get(i, j) < thr)
                .collect()
        })
        .collect();
    // Jaccard over dark sets *closed with the endpoints themselves* — for a
    // 2-SM group, i's only dark neighbor is j and vice versa, so the open
    // sets would be disjoint even though the pair is genuinely a group.
    let jaccard = |i: usize, j: usize| -> f64 {
        let (a, b) = (&dark[i], &dark[j]);
        let mut inter = 0usize;
        let mut union = 0usize;
        for k in 0..a.len() {
            let ak = a[k] || k == i;
            let bk = b[k] || k == j;
            inter += usize::from(ak && bk);
            union += usize::from(ak || bk);
        }
        if union == 0 {
            0.0
        } else {
            inter as f64 / union as f64
        }
    };
    let mut dsu = Dsu::new(m.n);
    for i in 0..m.n {
        for j in (i + 1)..m.n {
            if m.get(i, j) < thr && jaccard(i, j) >= 0.5 {
                dsu.union(i, j);
            }
        }
    }
    // Dense group ids in order of first appearance.
    let mut id_of_root = std::collections::HashMap::new();
    let mut group_of = vec![0usize; m.n];
    let mut groups: Vec<Vec<SmId>> = Vec::new();
    for sm in 0..m.n {
        let root = dsu.find(sm);
        let gid = *id_of_root.entry(root).or_insert_with(|| {
            groups.push(Vec::new());
            groups.len() - 1
        });
        group_of[sm] = gid;
        groups[gid].push(sm);
    }
    let mut permutation: Vec<SmId> = (0..m.n).collect();
    permutation.sort_by_key(|&s| (group_of[s], s));
    // Contrast of the two modes around the threshold.
    let (mut lo_sum, mut lo_n, mut hi_sum, mut hi_n) = (0.0, 0usize, 0.0, 0usize);
    for i in 0..m.n {
        for j in (i + 1)..m.n {
            let v = m.get(i, j);
            if v < thr {
                lo_sum += v;
                lo_n += 1;
            } else {
                hi_sum += v;
                hi_n += 1;
            }
        }
    }
    let contrast = if lo_n == 0 || hi_n == 0 {
        1.0
    } else {
        (hi_sum / hi_n as f64) / (lo_sum / lo_n as f64)
    };
    Clustering {
        group_of,
        groups,
        permutation,
        threshold: thr,
        contrast,
    }
}

/// Check the paper's Fig-2 structural observation: TPC mates (consecutive
/// smid pairs `(2k, 2k+1)`) always land in the same discovered group.
pub fn tpc_blocks_consistent(c: &Clustering) -> bool {
    c.group_of
        .chunks(2)
        .all(|pair| pair.len() < 2 || pair[0] == pair[1])
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::MachineConfig;
    use crate::probe::pair::{pair_probe, PairProbeConfig};
    use crate::sim::Machine;

    fn tiny_clustering() -> (Machine, Clustering) {
        let m = Machine::new(MachineConfig::tiny_test()).unwrap();
        let mut cfg = PairProbeConfig::for_machine(&m);
        cfg.accesses_per_sm = 2_000;
        cfg.workers = 4;
        let pm = pair_probe(&m, &cfg);
        let c = cluster(&pm);
        (m, c)
    }

    #[test]
    fn recovers_ground_truth_groups() {
        let (m, c) = tiny_clustering();
        let topo = m.topology();
        assert_eq!(c.groups.len(), topo.group_count());
        // Discovered labels must be a relabeling of ground truth.
        for i in 0..topo.sm_count() {
            for j in 0..topo.sm_count() {
                assert_eq!(
                    c.group_of[i] == c.group_of[j],
                    topo.group_of(i) == topo.group_of(j),
                    "smids {i},{j} mis-clustered"
                );
            }
        }
    }

    #[test]
    fn tpc_mates_clustered_together() {
        let (_m, c) = tiny_clustering();
        assert!(tpc_blocks_consistent(&c));
    }

    #[test]
    fn permutation_is_valid_and_group_sorted() {
        let (_m, c) = tiny_clustering();
        let mut sorted = c.permutation.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..c.group_of.len()).collect::<Vec<_>>());
        // Group ids must be nondecreasing along the permutation.
        let seq: Vec<usize> = c.permutation.iter().map(|&s| c.group_of[s]).collect();
        assert!(seq.windows(2).all(|w| w[0] <= w[1]));
    }

    #[test]
    fn groups_partition_all_sms() {
        let (_m, c) = tiny_clustering();
        let total: usize = c.groups.iter().map(|g| g.len()).sum();
        assert_eq!(total, c.group_of.len());
        for (gid, members) in c.groups.iter().enumerate() {
            for &sm in members {
                assert_eq!(c.group_of[sm], gid);
            }
        }
    }

    #[test]
    fn threshold_separates_modes() {
        let m = Machine::new(MachineConfig::tiny_test()).unwrap();
        let mut cfg = PairProbeConfig::for_machine(&m);
        cfg.accesses_per_sm = 2_000;
        cfg.workers = 4;
        let pm = pair_probe(&m, &cfg);
        let thr = auto_threshold(&pm);
        let topo = m.topology();
        for i in 0..pm.n {
            for j in (i + 1)..pm.n {
                let same = topo.group_of(i) == topo.group_of(j);
                assert_eq!(
                    pm.get(i, j) < thr,
                    same,
                    "pair ({i},{j}) same={same} thr={thr:.2} got={:.2}",
                    pm.get(i, j)
                );
            }
        }
    }
}

//! The AOT artifact manifest: what `python -m compile.aot` produced.
//!
//! `artifacts/manifest.json` lists every lowered executable with its entry
//! point and operand shapes, so the runtime can pick executables by
//! (entry, batch) without parsing HLO text.

use std::path::{Path, PathBuf};

use anyhow::{anyhow, Context};

use crate::util::json::Json;

/// One AOT-compiled artifact.
#[derive(Debug, Clone, PartialEq)]
pub struct ArtifactMeta {
    pub name: String,
    pub file: String,
    /// L2 entry point: "lookup" | "windowed_lookup" | "bag_forward" |
    /// "bag_loss_and_grad".
    pub entry: String,
    /// Batch size the executable was lowered for.
    pub b: usize,
    /// Table rows / row width it was lowered for.
    pub n: usize,
    pub d: usize,
    /// Bag size (bag entries only).
    pub g: Option<usize>,
    /// Operand order (runtime contract; e.g. windowed executables take
    /// `window` first).
    pub operands: Vec<String>,
}

/// Parsed manifest plus its directory (file paths are relative to it).
#[derive(Debug, Clone)]
pub struct Manifest {
    pub dir: PathBuf,
    pub artifacts: Vec<ArtifactMeta>,
}

impl Manifest {
    pub fn load(dir: &Path) -> anyhow::Result<Self> {
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {} (run `make artifacts`)", path.display()))?;
        Self::parse(dir, &text)
    }

    pub fn parse(dir: &Path, text: &str) -> anyhow::Result<Self> {
        let v = Json::parse(text)?;
        let version = v
            .get("version")
            .and_then(|x| x.as_u64())
            .ok_or_else(|| anyhow!("manifest missing version"))?;
        if version != 1 {
            return Err(anyhow!("unsupported manifest version {version}"));
        }
        let artifacts = v
            .get("artifacts")
            .and_then(|x| x.as_arr())
            .ok_or_else(|| anyhow!("manifest missing artifacts"))?
            .iter()
            .map(|a| {
                let req_str = |k: &str| -> anyhow::Result<String> {
                    Ok(a.get(k)
                        .and_then(|x| x.as_str())
                        .ok_or_else(|| anyhow!("artifact missing {k}"))?
                        .to_string())
                };
                let req_usize = |k: &str| -> anyhow::Result<usize> {
                    a.get(k)
                        .and_then(|x| x.as_usize())
                        .ok_or_else(|| anyhow!("artifact missing {k}"))
                };
                Ok(ArtifactMeta {
                    name: req_str("name")?,
                    file: req_str("file")?,
                    entry: req_str("entry")?,
                    b: req_usize("b")?,
                    n: req_usize("n")?,
                    d: req_usize("d")?,
                    g: a.get("g").and_then(|x| x.as_usize()),
                    operands: a
                        .get("operands")
                        .and_then(|x| x.as_arr())
                        .ok_or_else(|| anyhow!("artifact missing operands"))?
                        .iter()
                        .map(|o| {
                            Ok(o.as_str()
                                .ok_or_else(|| anyhow!("operand not a string"))?
                                .to_string())
                        })
                        .collect::<anyhow::Result<Vec<_>>>()?,
                })
            })
            .collect::<anyhow::Result<Vec<_>>>()?;
        if artifacts.is_empty() {
            return Err(anyhow!("manifest has no artifacts"));
        }
        Ok(Self {
            dir: dir.to_path_buf(),
            artifacts,
        })
    }

    /// Artifact by exact name.
    pub fn by_name(&self, name: &str) -> Option<&ArtifactMeta> {
        self.artifacts.iter().find(|a| a.name == name)
    }

    /// All artifacts for an entry point, sorted by batch size.
    pub fn by_entry(&self, entry: &str) -> Vec<&ArtifactMeta> {
        let mut v: Vec<&ArtifactMeta> =
            self.artifacts.iter().filter(|a| a.entry == entry).collect();
        v.sort_by_key(|a| a.b);
        v
    }

    /// Owned copy of the smallest-batch artifact of an entry (convenient
    /// for callers that then need `&mut` access to the runtime).
    pub fn first_of(&self, entry: &str) -> Option<ArtifactMeta> {
        self.by_entry(entry).first().map(|a| (*a).clone())
    }

    /// Smallest batch-size artifact of `entry` with `b >= want` (for batch
    /// padding), falling back to the largest available.
    pub fn pick(&self, entry: &str, want: usize) -> Option<&ArtifactMeta> {
        let candidates = self.by_entry(entry);
        candidates
            .iter()
            .find(|a| a.b >= want)
            .or_else(|| candidates.last())
            .copied()
    }

    /// Absolute path of an artifact's HLO text.
    pub fn path_of(&self, a: &ArtifactMeta) -> PathBuf {
        self.dir.join(&a.file)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"{
      "version": 1,
      "artifacts": [
        {"name": "gather_b256_n65536_d32", "file": "gather_b256_n65536_d32.hlo.txt",
         "entry": "lookup", "b": 256, "n": 65536, "d": 32, "operands": ["indices", "table"]},
        {"name": "gather_b1024_n65536_d32", "file": "gather_b1024_n65536_d32.hlo.txt",
         "entry": "lookup", "b": 1024, "n": 65536, "d": 32, "operands": ["indices", "table"]},
        {"name": "bag_fwd_b256_g8_n65536_d32", "file": "bag_fwd_b256_g8_n65536_d32.hlo.txt",
         "entry": "bag_forward", "b": 256, "g": 8, "n": 65536, "d": 32,
         "operands": ["indices", "table"]}
      ]
    }"#;

    #[test]
    fn parses_sample() {
        let m = Manifest::parse(Path::new("/tmp/x"), SAMPLE).unwrap();
        assert_eq!(m.artifacts.len(), 3);
        assert_eq!(m.artifacts[0].entry, "lookup");
        assert_eq!(m.artifacts[2].g, Some(8));
    }

    #[test]
    fn by_entry_sorted() {
        let m = Manifest::parse(Path::new("/tmp/x"), SAMPLE).unwrap();
        let v = m.by_entry("lookup");
        assert_eq!(v.len(), 2);
        assert!(v[0].b < v[1].b);
    }

    #[test]
    fn pick_rounds_up_then_saturates() {
        let m = Manifest::parse(Path::new("/tmp/x"), SAMPLE).unwrap();
        assert_eq!(m.pick("lookup", 10).unwrap().b, 256);
        assert_eq!(m.pick("lookup", 256).unwrap().b, 256);
        assert_eq!(m.pick("lookup", 257).unwrap().b, 1024);
        assert_eq!(m.pick("lookup", 5000).unwrap().b, 1024); // saturate
        assert!(m.pick("nonexistent", 1).is_none());
    }

    #[test]
    fn rejects_bad_manifests() {
        assert!(Manifest::parse(Path::new("/"), "{}").is_err());
        assert!(Manifest::parse(Path::new("/"), r#"{"version": 2, "artifacts": []}"#).is_err());
        assert!(Manifest::parse(Path::new("/"), r#"{"version": 1, "artifacts": []}"#).is_err());
        let missing_field = r#"{"version":1,"artifacts":[{"name":"x","file":"y","entry":"lookup","b":1,"n":2,"operands":[]}]}"#;
        assert!(Manifest::parse(Path::new("/"), missing_field).is_err());
    }

    #[test]
    fn path_of_joins_dir() {
        let m = Manifest::parse(Path::new("/a/b"), SAMPLE).unwrap();
        assert_eq!(
            m.path_of(&m.artifacts[0]),
            PathBuf::from("/a/b/gather_b256_n65536_d32.hlo.txt")
        );
    }
}

//! PJRT runtime: load AOT-compiled HLO text, compile once, execute from the
//! Rust hot path.  Python never runs here.
//!
//! Pattern (from /opt/xla-example/load_hlo): `PjRtClient::cpu()` ->
//! `HloModuleProto::from_text_file` -> `XlaComputation::from_proto` ->
//! `client.compile` -> `execute_b`.  HLO *text* is the interchange format —
//! jax >= 0.5 emits protos with 64-bit instruction ids that xla_extension
//! 0.5.1 rejects; the text parser reassigns ids.
//!
//! Thread model: PJRT handles are not `Send`, so each coordinator worker
//! owns its own [`Runtime`] (its own CPU client + executable cache + shard
//! buffer).  That mirrors the paper's architecture anyway: one execution
//! domain per SM resource group.

pub mod hlo_info;
pub mod manifest;

use std::collections::HashMap;
use std::path::{Path, PathBuf};

use anyhow::{anyhow, Context};

pub use hlo_info::{inspect_file, HloInfo};
pub use manifest::{ArtifactMeta, Manifest};

/// A compiled artifact cache bound to one PJRT (CPU) client.
pub struct Runtime {
    client: xla::PjRtClient,
    manifest: Manifest,
    exes: HashMap<String, xla::PjRtLoadedExecutable>,
}

impl Runtime {
    /// Create a runtime over an artifacts directory (must contain
    /// `manifest.json`; run `make artifacts` first).
    pub fn new(artifacts_dir: &Path) -> anyhow::Result<Self> {
        let manifest = Manifest::load(artifacts_dir)?;
        let client = xla::PjRtClient::cpu().map_err(|e| anyhow!("PJRT cpu client: {e:?}"))?;
        Ok(Self {
            client,
            manifest,
            exes: HashMap::new(),
        })
    }

    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Compile (or fetch from cache) an artifact by name.
    pub fn ensure_compiled(&mut self, name: &str) -> anyhow::Result<()> {
        if self.exes.contains_key(name) {
            return Ok(());
        }
        let meta = self
            .manifest
            .by_name(name)
            .ok_or_else(|| anyhow!("no artifact named {name}"))?;
        let path: PathBuf = self.manifest.path_of(meta);
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().ok_or_else(|| anyhow!("non-utf8 path"))?,
        )
        .map_err(|e| anyhow!("parsing {}: {e:?}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .map_err(|e| anyhow!("compiling {name}: {e:?}"))?;
        self.exes.insert(name.to_string(), exe);
        Ok(())
    }

    pub fn is_compiled(&self, name: &str) -> bool {
        self.exes.contains_key(name)
    }

    /// Upload a host f32 tensor as a device buffer (e.g. the table shard).
    pub fn upload_f32(&self, data: &[f32], dims: &[usize]) -> anyhow::Result<xla::PjRtBuffer> {
        self.client
            .buffer_from_host_buffer(data, dims, None)
            .map_err(|e| anyhow!("upload f32 {dims:?}: {e:?}"))
    }

    /// Upload a host i32 tensor (indices / window descriptors).
    pub fn upload_i32(&self, data: &[i32], dims: &[usize]) -> anyhow::Result<xla::PjRtBuffer> {
        self.client
            .buffer_from_host_buffer(data, dims, None)
            .map_err(|e| anyhow!("upload i32 {dims:?}: {e:?}"))
    }

    /// Execute a compiled artifact on device buffers; returns the elements
    /// of the result tuple as host literals.
    pub fn execute(
        &self,
        name: &str,
        args: &[&xla::PjRtBuffer],
    ) -> anyhow::Result<Vec<xla::Literal>> {
        let exe = self
            .exes
            .get(name)
            .ok_or_else(|| anyhow!("{name} not compiled (call ensure_compiled)"))?;
        let outs = exe
            .execute_b(args)
            .map_err(|e| anyhow!("executing {name}: {e:?}"))?;
        let lit = outs[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("fetch result of {name}: {e:?}"))?;
        // aot.py lowers with return_tuple=True: every result is a tuple.
        lit.to_tuple().map_err(|e| anyhow!("untuple {name}: {e:?}"))
    }

    /// Convenience: run a `lookup` gather of `indices` against an uploaded
    /// table, returning the flat row data (len = b * d).
    pub fn gather(
        &mut self,
        name: &str,
        indices: &[i32],
        table: &xla::PjRtBuffer,
    ) -> anyhow::Result<Vec<f32>> {
        let meta = self
            .manifest
            .by_name(name)
            .ok_or_else(|| anyhow!("no artifact {name}"))?
            .clone();
        if indices.len() != meta.b {
            return Err(anyhow!(
                "artifact {name} wants batch {}, got {}",
                meta.b,
                indices.len()
            ));
        }
        self.ensure_compiled(name)?;
        let idx = self.upload_i32(indices, &[meta.b])?;
        let outs = self.execute(name, &[&idx, table])?;
        outs[0]
            .to_vec::<f32>()
            .map_err(|e| anyhow!("result of {name}: {e:?}"))
    }

    /// Convenience: windowed gather (operands: window [2], indices [b],
    /// table [n, d]).
    pub fn windowed_gather(
        &mut self,
        name: &str,
        window: [i32; 2],
        indices: &[i32],
        table: &xla::PjRtBuffer,
    ) -> anyhow::Result<Vec<f32>> {
        let meta = self
            .manifest
            .by_name(name)
            .ok_or_else(|| anyhow!("no artifact {name}"))?
            .clone();
        if meta.operands.first().map(String::as_str) != Some("window") {
            return Err(anyhow!("artifact {name} is not a windowed entry"));
        }
        if indices.len() != meta.b {
            return Err(anyhow!(
                "artifact {name} wants batch {}, got {}",
                meta.b,
                indices.len()
            ));
        }
        self.ensure_compiled(name)?;
        let win = self.upload_i32(&window, &[2])?;
        let idx = self.upload_i32(indices, &[meta.b])?;
        let outs = self.execute(name, &[&win, &idx, table])?;
        outs[0]
            .to_vec::<f32>()
            .map_err(|e| anyhow!("result of {name}: {e:?}"))
    }

    /// Locate the artifacts directory: `$A100WIN_ARTIFACTS`, else
    /// `./artifacts`, else `../artifacts` (for tests running in target/).
    pub fn default_artifacts_dir() -> anyhow::Result<PathBuf> {
        if let Ok(p) = std::env::var("A100WIN_ARTIFACTS") {
            return Ok(PathBuf::from(p));
        }
        for cand in ["artifacts", "../artifacts"] {
            let p = PathBuf::from(cand);
            if p.join("manifest.json").exists() {
                return Ok(p);
            }
        }
        Err(anyhow!(
            "no artifacts directory found; run `make artifacts` or set A100WIN_ARTIFACTS"
        ))
        .context("locating AOT artifacts")
    }
}

// Runtime tests need compiled artifacts on disk; they live in
// rust/tests/runtime_roundtrip.rs so `cargo test --lib` stays artifact-free.

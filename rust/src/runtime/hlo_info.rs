//! HLO text inspection: a lightweight census of the AOT artifacts.
//!
//! This is the L2 profiling tool of DESIGN.md §8: without executing
//! anything it answers "did the kernel lower to the shape we intended?" —
//! the vectorized gather must contain a real `gather` op and **no**
//! `while` loop, the bag kernel must fuse its reduce, the training artifact
//! must carry exactly one scatter(-add).  Tests in
//! `rust/tests/runtime_roundtrip.rs` enforce those properties for every
//! artifact in the manifest, so an accidental re-introduction of the slow
//! loop lowering (EXPERIMENTS.md §Perf L1 iteration 0: 68x slower) fails CI
//! rather than shipping.

use std::collections::BTreeMap;
use std::path::Path;

use anyhow::Context;

/// Census of one HLO module.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct HloInfo {
    /// Opcode -> occurrence count (entry + nested computations).
    pub op_counts: BTreeMap<String, usize>,
    /// Number of computations (fusions/branches/loops bodies + entry).
    pub computations: usize,
    /// Total instruction count.
    pub instructions: usize,
    /// Parameters of the entry computation, in order: (name, type string).
    pub entry_params: Vec<(String, String)>,
}

impl HloInfo {
    pub fn count(&self, op: &str) -> usize {
        self.op_counts.get(op).copied().unwrap_or(0)
    }

    pub fn has_while(&self) -> bool {
        self.count("while") > 0
    }

    pub fn has_gather(&self) -> bool {
        self.count("gather") > 0
    }

    pub fn has_scatter(&self) -> bool {
        self.count("scatter") > 0
    }
}

/// Parse HLO *text* (as emitted by aot.py / `as_hlo_text`).
///
/// The format is line-oriented:
/// ```text
/// HloModule jit_lookup, entry_computation_layout=...
///
/// %fused_computation (...) -> ... {
///   %param_0.1 = f32[65536,32]{1,0} parameter(0)
///   ROOT %gather.2 = f32[256,32]{1,0} gather(...), offset_dims=...
/// }
///
/// ENTRY %main.10 (...) -> ... {
///   ...
/// }
/// ```
/// An instruction line is `[ROOT] %name = type opcode(args), attrs`.
pub fn parse_hlo_text(text: &str) -> anyhow::Result<HloInfo> {
    let mut info = HloInfo::default();
    let mut in_entry = false;
    for raw in text.lines() {
        let line = raw.trim();
        if line.is_empty() || line.starts_with("HloModule") {
            continue;
        }
        // Computation header: `name {`, `name (params) -> result {`, or
        // `ENTRY ...` — the text emitter may or may not prefix names with
        // '%' depending on the HloPrintOptions used.
        if line.ends_with('{') && !line.contains('=') {
            info.computations += 1;
            in_entry = line.starts_with("ENTRY");
            continue;
        }
        if line == "}" {
            in_entry = false;
            continue;
        }
        // Instruction: [ROOT] [%]name = type opcode(...)
        let body = line.strip_prefix("ROOT ").unwrap_or(line);
        let rest = body.strip_prefix('%').unwrap_or(body);
        let Some(eq) = rest.find(" = ") else { continue };
        let name = &rest[..eq];
        if name.contains(' ') {
            continue; // not an instruction line
        }
        let after = &rest[eq + 3..];
        // after = "f32[256,32]{1,0} opcode(args), attrs"
        let mut parts = after.splitn(2, ' ');
        let ty = parts.next().unwrap_or("");
        let Some(opcall) = parts.next() else { continue };
        let opcode: String = opcall
            .chars()
            .take_while(|c| c.is_ascii_alphanumeric() || *c == '-' || *c == '_')
            .collect();
        if opcode.is_empty() {
            continue;
        }
        if in_entry && opcode == "parameter" {
            info.entry_params.push((name.to_string(), ty.to_string()));
        }
        *info.op_counts.entry(opcode).or_insert(0) += 1;
        info.instructions += 1;
    }
    if info.computations == 0 {
        anyhow::bail!("no computations found: not HLO text?");
    }
    Ok(info)
}

/// Parse an artifact file.
pub fn inspect_file(path: &Path) -> anyhow::Result<HloInfo> {
    let text =
        std::fs::read_to_string(path).with_context(|| format!("reading {}", path.display()))?;
    parse_hlo_text(&text)
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"HloModule jit_lookup, entry_computation_layout={(s32[8]{0}, f32[16,4]{1,0})->(f32[8,4]{1,0})}

%fused_computation (param_0.1: f32[16,4], param_1.2: s32[8]) -> f32[8,4] {
  %param_1.2 = s32[8]{0} parameter(1)
  %param_0.1 = f32[16,4]{1,0} parameter(0)
  ROOT %gather.1 = f32[8,4]{1,0} gather(f32[16,4]{1,0} %param_0.1, s32[8]{0} %param_1.2), offset_dims={1}
}

ENTRY %main.5 (Arg_0.1: s32[8], Arg_1.2: f32[16,4]) -> (f32[8,4]) {
  %Arg_0.1 = s32[8]{0} parameter(0)
  %Arg_1.2 = f32[16,4]{1,0} parameter(1)
  %fusion = f32[8,4]{1,0} fusion(f32[16,4]{1,0} %Arg_1.2, s32[8]{0} %Arg_0.1), kind=kLoop, calls=%fused_computation
  ROOT %tuple.4 = (f32[8,4]{1,0}) tuple(f32[8,4]{1,0} %fusion)
}
"#;

    #[test]
    fn parses_sample() {
        let info = parse_hlo_text(SAMPLE).unwrap();
        assert_eq!(info.computations, 2);
        assert!(info.has_gather());
        assert!(!info.has_while());
        assert_eq!(info.count("parameter"), 4);
        assert_eq!(info.count("fusion"), 1);
        assert_eq!(info.count("tuple"), 1);
        assert_eq!(info.instructions, 4 + 1 + 1 + 1);
    }

    #[test]
    fn entry_params_only_from_entry() {
        let info = parse_hlo_text(SAMPLE).unwrap();
        assert_eq!(info.entry_params.len(), 2);
        assert_eq!(info.entry_params[0].0, "Arg_0.1");
        assert!(info.entry_params[0].1.starts_with("s32[8]"));
        assert!(info.entry_params[1].1.starts_with("f32[16,4]"));
    }

    #[test]
    fn rejects_non_hlo() {
        assert!(parse_hlo_text("this is not hlo").is_err());
        assert!(parse_hlo_text("").is_err());
    }

    #[test]
    fn counts_while_ops() {
        let src = "ENTRY %m (a: s32[]) -> s32[] {\n  %a = s32[] parameter(0)\n  ROOT %while.1 = s32[] while(s32[] %a), condition=%c, body=%b\n}\n";
        let info = parse_hlo_text(src).unwrap();
        assert!(info.has_while());
        assert_eq!(info.count("while"), 1);
    }
}

//! Model-aware atomics, API-compatible with `std::sync::atomic`.
//!
//! Each wrapper embeds the real `std` atomic as a *mirror*: in pass-through
//! mode (no active execution on this thread) every method delegates to it
//! 1:1; under a model execution the operation routes through the scheduler
//! and the mirror is kept at the model's newest value (updated while the
//! execution lock serializes threads), so `get_mut`/`into_inner` after the
//! execution — and location initialization on first touch — stay exact.

pub use std::sync::atomic::Ordering;

use crate::ctx;

/// Model-aware equivalent of [`std::sync::atomic::fence`].
pub fn fence(ord: Ordering) {
    match ctx::current() {
        Some(c) => c.exec.fence(c.tid, ord),
        None => std::sync::atomic::fence(ord),
    }
}

macro_rules! model_int_atomic {
    ($name:ident, $std:ty, $prim:ty) => {
        pub struct $name {
            plain: $std,
        }

        impl $name {
            pub const fn new(v: $prim) -> Self {
                Self { plain: <$std>::new(v) }
            }

            fn addr(&self) -> usize {
                &self.plain as *const _ as usize
            }

            /// Mirror value for location init; only read while this thread
            /// is the single active model thread, so never racy.
            fn init(&self) -> u64 {
                self.plain.load(Ordering::Relaxed) as u64
            }

            pub fn load(&self, ord: Ordering) -> $prim {
                match ctx::current() {
                    Some(c) => {
                        c.exec.atomic_load(c.tid, self.addr(), ord, self.init()) as $prim
                    }
                    None => self.plain.load(ord),
                }
            }

            pub fn store(&self, val: $prim, ord: Ordering) {
                match ctx::current() {
                    Some(c) => c.exec.atomic_store(
                        c.tid,
                        self.addr(),
                        ord,
                        val as u64,
                        self.init(),
                        |v| self.plain.store(v as $prim, Ordering::Relaxed),
                    ),
                    None => self.plain.store(val, ord),
                }
            }

            pub fn swap(&self, val: $prim, ord: Ordering) -> $prim {
                self.rmw(ord, move |_| val, |p| p.swap(val, ord))
            }

            pub fn fetch_add(&self, val: $prim, ord: Ordering) -> $prim {
                self.rmw(ord, move |old| old.wrapping_add(val), |p| p.fetch_add(val, ord))
            }

            pub fn fetch_sub(&self, val: $prim, ord: Ordering) -> $prim {
                self.rmw(ord, move |old| old.wrapping_sub(val), |p| p.fetch_sub(val, ord))
            }

            pub fn fetch_or(&self, val: $prim, ord: Ordering) -> $prim {
                self.rmw(ord, move |old| old | val, |p| p.fetch_or(val, ord))
            }

            pub fn fetch_and(&self, val: $prim, ord: Ordering) -> $prim {
                self.rmw(ord, move |old| old & val, |p| p.fetch_and(val, ord))
            }

            pub fn fetch_max(&self, val: $prim, ord: Ordering) -> $prim {
                self.rmw(ord, move |old| old.max(val), |p| p.fetch_max(val, ord))
            }

            fn rmw(
                &self,
                ord: Ordering,
                compute: impl Fn($prim) -> $prim,
                plain: impl FnOnce(&$std) -> $prim,
            ) -> $prim {
                match ctx::current() {
                    Some(c) => c.exec.atomic_rmw(
                        c.tid,
                        self.addr(),
                        ord,
                        self.init(),
                        |old| compute(old as $prim) as u64,
                        |v| self.plain.store(v as $prim, Ordering::Relaxed),
                    ) as $prim,
                    None => plain(&self.plain),
                }
            }

            pub fn compare_exchange(
                &self,
                current: $prim,
                new: $prim,
                success: Ordering,
                failure: Ordering,
            ) -> Result<$prim, $prim> {
                match ctx::current() {
                    Some(c) => c
                        .exec
                        .atomic_cas(
                            c.tid,
                            self.addr(),
                            current as u64,
                            new as u64,
                            success,
                            failure,
                            self.init(),
                            |v| self.plain.store(v as $prim, Ordering::Relaxed),
                        )
                        .map(|v| v as $prim)
                        .map_err(|v| v as $prim),
                    None => self.plain.compare_exchange(current, new, success, failure),
                }
            }

            /// Modeled as a strong CAS: never fails spuriously. Spurious
            /// failures only add retry iterations, which the scheduler's
            /// interleaving choices already cover.
            pub fn compare_exchange_weak(
                &self,
                current: $prim,
                new: $prim,
                success: Ordering,
                failure: Ordering,
            ) -> Result<$prim, $prim> {
                match ctx::current() {
                    Some(_) => self.compare_exchange(current, new, success, failure),
                    None => self
                        .plain
                        .compare_exchange_weak(current, new, success, failure),
                }
            }

            pub fn get_mut(&mut self) -> &mut $prim {
                self.plain.get_mut()
            }

            pub fn into_inner(self) -> $prim {
                self.plain.into_inner()
            }
        }

        impl Default for $name {
            fn default() -> Self {
                Self::new(0)
            }
        }

        impl std::fmt::Debug for $name {
            fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
                // Debug reads bypass the scheduler: diagnostics only.
                write!(f, "{:?}", self.plain)
            }
        }
    };
}

model_int_atomic!(AtomicU8, std::sync::atomic::AtomicU8, u8);
model_int_atomic!(AtomicU64, std::sync::atomic::AtomicU64, u64);
model_int_atomic!(AtomicUsize, std::sync::atomic::AtomicUsize, usize);

pub struct AtomicBool {
    plain: std::sync::atomic::AtomicBool,
}

impl AtomicBool {
    pub const fn new(v: bool) -> Self {
        Self {
            plain: std::sync::atomic::AtomicBool::new(v),
        }
    }

    fn addr(&self) -> usize {
        &self.plain as *const _ as usize
    }

    fn init(&self) -> u64 {
        self.plain.load(Ordering::Relaxed) as u64
    }

    pub fn load(&self, ord: Ordering) -> bool {
        match ctx::current() {
            Some(c) => c.exec.atomic_load(c.tid, self.addr(), ord, self.init()) != 0,
            None => self.plain.load(ord),
        }
    }

    pub fn store(&self, val: bool, ord: Ordering) {
        match ctx::current() {
            Some(c) => c.exec.atomic_store(
                c.tid,
                self.addr(),
                ord,
                val as u64,
                self.init(),
                |v| self.plain.store(v != 0, Ordering::Relaxed),
            ),
            None => self.plain.store(val, ord),
        }
    }

    pub fn swap(&self, val: bool, ord: Ordering) -> bool {
        match ctx::current() {
            Some(c) => {
                c.exec.atomic_rmw(
                    c.tid,
                    self.addr(),
                    ord,
                    self.init(),
                    |_| val as u64,
                    |v| self.plain.store(v != 0, Ordering::Relaxed),
                ) != 0
            }
            None => self.plain.swap(val, ord),
        }
    }

    pub fn compare_exchange(
        &self,
        current: bool,
        new: bool,
        success: Ordering,
        failure: Ordering,
    ) -> Result<bool, bool> {
        match ctx::current() {
            Some(c) => c
                .exec
                .atomic_cas(
                    c.tid,
                    self.addr(),
                    current as u64,
                    new as u64,
                    success,
                    failure,
                    self.init(),
                    |v| self.plain.store(v != 0, Ordering::Relaxed),
                )
                .map(|v| v != 0)
                .map_err(|v| v != 0),
            None => self.plain.compare_exchange(current, new, success, failure),
        }
    }

    /// See the integer atomics: modeled as a strong CAS.
    pub fn compare_exchange_weak(
        &self,
        current: bool,
        new: bool,
        success: Ordering,
        failure: Ordering,
    ) -> Result<bool, bool> {
        match ctx::current() {
            Some(_) => self.compare_exchange(current, new, success, failure),
            None => self
                .plain
                .compare_exchange_weak(current, new, success, failure),
        }
    }

    pub fn get_mut(&mut self) -> &mut bool {
        self.plain.get_mut()
    }

    pub fn into_inner(self) -> bool {
        self.plain.into_inner()
    }
}

impl Default for AtomicBool {
    fn default() -> Self {
        Self::new(false)
    }
}

impl std::fmt::Debug for AtomicBool {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{:?}", self.plain)
    }
}

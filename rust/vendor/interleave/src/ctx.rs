//! Thread-local execution context: which model execution (if any) the
//! current OS thread belongs to. Absent context = pass-through mode, where
//! every shim primitive behaves exactly like its `std` counterpart.

use std::cell::RefCell;
use std::sync::Arc;

use crate::exec::Exec;

#[derive(Clone)]
pub(crate) struct Ctx {
    pub(crate) exec: Arc<Exec>,
    pub(crate) tid: usize,
}

thread_local! {
    static CURRENT: RefCell<Option<Ctx>> = RefCell::new(None);
}

pub(crate) fn current() -> Option<Ctx> {
    CURRENT.with(|c| c.borrow().clone())
}

pub(crate) fn set(v: Option<Ctx>) {
    CURRENT.with(|c| *c.borrow_mut() = v);
}

pub(crate) fn in_model() -> bool {
    CURRENT.with(|c| c.borrow().is_some())
}

//! interleave — in-tree model checker for the a100win lock-free serving path.
//!
//! Shuttle/loom-style checker with zero external dependencies: real OS
//! threads are serialized one-at-a-time by a global scheduler, every
//! atomic/mutex/park operation is a recorded choice point, and the driver
//! replays choice prefixes to explore interleavings — exhaustive DFS under
//! a context-switch (preemption) bound, or seeded randomized scheduling for
//! larger models. Per-location vector clocks flag unsynchronized accesses
//! to [`cell::RaceCell`]s *before* the racing access executes, and a
//! bounded per-location store history models `Relaxed` visibility (a store
//! published without release/acquire ordering may be observed stale).
//!
//! The model is deliberately a documented approximation, slightly stronger
//! than C11 where exactness would cost tractability (see `exec.rs` docs):
//! it can miss some exotic weak-memory behaviors, but every failure it
//! reports corresponds to a real schedule + visibility choice.
//!
//! Usage from a `#[test]`:
//!
//! ```ignore
//! interleave::model(|| {
//!     let flag = Arc::new(interleave::atomic::AtomicBool::new(false));
//!     let t = interleave::thread::spawn({ let f = flag.clone(); move || f.store(true, Ordering::SeqCst) });
//!     // ... assertions ...
//!     t.join().unwrap();
//! });
//! ```
//!
//! Caveats:
//! - At most [`clock::MAX_THREADS`] threads per execution (incl. main).
//! - Construct all model state *inside* the closure: executions replay the
//!   closure from scratch, and the checker keys locations by address, so
//!   freeing and reallocating an atomic at the same address within one
//!   execution confuses the per-location history.
//! - `park_timeout` behaves as `park` under the model: a passing model
//!   proves the protocol correct *without* its timeout backstops.

mod clock;
mod ctx;
mod exec;
mod rng;

pub mod atomic;
pub mod cell;
pub mod sync;
pub mod thread;

use exec::{ChoicePoint, Exec, Mode};
use rng::Rng;
use std::sync::Arc;

/// Exploration limits. Defaults keep small 2–3 thread models exhaustive in
/// well under a second while bounding pathological state spaces.
#[derive(Clone, Debug)]
pub struct Config {
    /// Max *preemptive* context switches per execution (switches at a
    /// non-yielding op while the current thread could continue). The classic
    /// small-bound hypothesis: most concurrency bugs need <= 2 preemptions.
    pub preemption_bound: usize,
    /// How many stale (non-newest) stores a relaxed load may observe
    /// (per-location history depth beyond the newest store).
    pub stale_depth: usize,
    /// Max stale-value choices across one execution (keeps the value-choice
    /// branching factor bounded independently of schedule length).
    pub stale_budget: usize,
    /// Hard cap on DFS executions; exceeded => `Report::complete == false`.
    pub max_executions: usize,
    /// Per-execution op budget; exceeded => Livelock failure.
    pub max_ops: u64,
}

impl Default for Config {
    fn default() -> Self {
        Config {
            preemption_bound: 2,
            stale_depth: 1,
            stale_budget: 2,
            max_executions: 50_000,
            max_ops: 200_000,
        }
    }
}

#[derive(Clone, Debug, PartialEq, Eq)]
pub enum FailureKind {
    /// Every unfinished thread was blocked (lost wakeup, missed unpark...).
    Deadlock,
    /// Unordered concurrent accesses to a [`cell::RaceCell`].
    DataRace,
    /// User code panicked (assertion failure inside the model).
    Panic,
    /// Per-execution op budget exceeded (unbounded spin under the model).
    Livelock,
}

#[derive(Clone, Debug)]
pub struct Failure {
    pub kind: FailureKind,
    pub message: String,
    /// Choice trace reproducing the failing execution (for diagnostics).
    pub schedule: Vec<u8>,
}

#[derive(Debug)]
pub struct Report {
    /// Executions explored.
    pub executions: usize,
    /// True iff DFS exhausted the (bounded) state space with no failure.
    pub complete: bool,
    pub failure: Option<Failure>,
}

fn payload_msg(p: &(dyn std::any::Any + Send)) -> String {
    if let Some(s) = p.downcast_ref::<&'static str>() {
        (*s).to_string()
    } else if let Some(s) = p.downcast_ref::<String>() {
        s.clone()
    } else {
        "non-string panic payload".to_string()
    }
}

/// Suppress panic output from inside model executions: aborts and probed
/// assertion failures unwind by design and are re-reported by the driver.
fn install_hook() {
    use std::sync::Once;
    static HOOK: Once = Once::new();
    HOOK.call_once(|| {
        let prev = std::panic::take_hook();
        std::panic::set_hook(Box::new(move |info| {
            if ctx::in_model() {
                return;
            }
            prev(info);
        }));
    });
}

fn run_once(cfg: &Config, mode: Mode, f: &dyn Fn()) -> (Vec<ChoicePoint>, Option<Failure>) {
    let exec = Arc::new(Exec::new(cfg.clone(), mode));
    ctx::set(Some(ctx::Ctx {
        exec: exec.clone(),
        tid: 0,
    }));
    let r = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| f()));
    if let Err(p) = &r {
        exec.record_panic_payload(p.as_ref());
    }
    exec.finish_main_and_wait();
    ctx::set(None);
    exec.outcome()
}

/// Backtrack: find the deepest choice with an untried alternative.
fn next_prefix(record: &[ChoicePoint]) -> Option<Vec<u8>> {
    let mut i = record.len();
    while i > 0 {
        i -= 1;
        if record[i].chosen + 1 < record[i].options {
            let mut p: Vec<u8> = record[..i].iter().map(|c| c.chosen).collect();
            p.push(record[i].chosen + 1);
            return Some(p);
        }
    }
    None
}

/// Exhaustive bounded DFS over schedules + stale-visibility choices.
/// Stops at the first failure (its `schedule` reproduces it).
pub fn explore(cfg: Config, f: impl Fn()) -> Report {
    install_hook();
    let mut prefix: Vec<u8> = Vec::new();
    let mut executions = 0usize;
    loop {
        let (record, failure) = run_once(&cfg, Mode::Dfs { prefix }, &f);
        executions += 1;
        if failure.is_some() {
            return Report {
                executions,
                complete: false,
                failure,
            };
        }
        match next_prefix(&record) {
            None => {
                return Report {
                    executions,
                    complete: true,
                    failure: None,
                }
            }
            Some(p) => {
                if executions >= cfg.max_executions {
                    return Report {
                        executions,
                        complete: false,
                        failure: None,
                    };
                }
                prefix = p;
            }
        }
    }
}

/// Seeded randomized (shuttle-style) exploration: `iters` executions with
/// uniform choices; preemption bound is still honored from `cfg`.
pub fn explore_random(cfg: Config, seed: u64, iters: usize, f: impl Fn()) -> Report {
    install_hook();
    let mut executions = 0usize;
    for i in 0..iters {
        let rng = Rng::seed_from_u64(seed ^ (i as u64).wrapping_mul(0x9e37_79b9_7f4a_7c15));
        let (_, failure) = run_once(&cfg, Mode::Random { rng }, &f);
        executions += 1;
        if failure.is_some() {
            return Report {
                executions,
                complete: false,
                failure,
            };
        }
    }
    Report {
        executions,
        complete: false,
        failure: None,
    }
}

fn expect_clean(what: &str, r: Report) {
    if let Some(fl) = r.failure {
        panic!(
            "{what} failed after {} executions: {:?}: {} (schedule {:?})",
            r.executions, fl.kind, fl.message, fl.schedule
        );
    }
}

/// Run `f` under the default exhaustive configuration; panic on any race,
/// deadlock, livelock, or in-model assertion failure.
pub fn model(f: impl Fn()) {
    expect_clean("model checking", explore(Config::default(), f));
}

/// [`model`] with an explicit configuration.
pub fn model_with(cfg: Config, f: impl Fn()) {
    expect_clean("model checking", explore(cfg, f));
}

/// Randomized supplement for state spaces too large to exhaust: `iters`
/// seeded executions with unbounded preemptions.
pub fn model_random(seed: u64, iters: usize, f: impl Fn()) {
    let cfg = Config {
        preemption_bound: usize::MAX,
        ..Config::default()
    };
    expect_clean("randomized model checking", explore_random(cfg, seed, iters, f));
}

//! Tiny deterministic RNG for randomized (shuttle-style) scheduling.
//!
//! xorshift64* — not cryptographic, but plenty for schedule sampling,
//! and dependency-free so the vendored crate stays self-contained.

#[derive(Clone, Debug)]
pub(crate) struct Rng(u64);

impl Rng {
    pub(crate) fn seed_from_u64(seed: u64) -> Self {
        // Avoid the all-zero fixed point.
        Rng(seed.wrapping_mul(0x9e37_79b9_7f4a_7c15) | 1)
    }

    fn next_u64(&mut self) -> u64 {
        let mut x = self.0;
        x ^= x >> 12;
        x ^= x << 25;
        x ^= x >> 27;
        self.0 = x;
        x.wrapping_mul(0x2545_f491_4f6c_dd1d)
    }

    /// Uniform-ish choice in `0..n` (n >= 1, tiny n so modulo bias is moot).
    pub(crate) fn below(&mut self, n: u8) -> u8 {
        debug_assert!(n >= 1);
        (self.next_u64() % n as u64) as u8
    }
}

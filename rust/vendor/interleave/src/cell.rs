//! [`RaceCell`]: an `UnsafeCell` that the model checker watches.
//!
//! API-compatible with `core::cell::UnsafeCell` for the operations the
//! serving path uses (`get`, `get_mut`, `into_inner`). In pass-through mode
//! `get()` is exactly `UnsafeCell::get`. Under a model execution every
//! `get()` is recorded with the caller's vector clock; two accesses by
//! different threads without a happens-before edge between them are flagged
//! as a data race and the execution aborts *before* the unsynchronized
//! pointer is dereferenced — the checker fails the schedule instead of
//! executing the UB.
//!
//! Conservative by design: every `get()` counts as a write (the serving
//! path hands these pointers out precisely to write through them), so
//! read-read false positives are possible in principle but do not occur in
//! the ported primitives, where reads of one-shot cells are always ordered
//! by an acquire on the owning flag.

use std::cell::UnsafeCell;

use crate::ctx;

pub struct RaceCell<T> {
    inner: UnsafeCell<T>,
}

impl<T> RaceCell<T> {
    pub const fn new(v: T) -> Self {
        RaceCell {
            inner: UnsafeCell::new(v),
        }
    }

    pub fn into_inner(self) -> T {
        self.inner.into_inner()
    }

    /// Raw pointer to the contents; under the model, records the access and
    /// aborts the execution on an unordered racing access.
    pub fn get(&self) -> *mut T {
        if let Some(c) = ctx::current() {
            c.exec.cell_access(c.tid, self.inner.get() as usize);
        }
        self.inner.get()
    }

    /// Exclusive access needs no race tracking: `&mut self` proves it.
    pub fn get_mut(&mut self) -> &mut T {
        self.inner.get_mut()
    }
}

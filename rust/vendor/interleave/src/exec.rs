//! Execution core: one serialized schedule of real OS threads.
//!
//! Every visible operation (atomic access, mutex op, park/unpark, spawn,
//! join, yield) funnels through [`Exec`]: the calling thread takes the
//! global state lock, lets the scheduler decide whether to hand the CPU to
//! another runnable thread (a *choice point*, recorded for DFS replay),
//! performs the operation against the modeled memory, and releases the
//! lock. Exactly one model thread runs user code at a time, so the modeled
//! memory needs no synchronization of its own.
//!
//! Memory model (documented approximation, slightly *stronger* than C11):
//! - Per-location bounded store history; a non-SC load may observe a stale
//!   store (a scheduler-visible value choice) unless a newer store to the
//!   same location happens-before the reader.
//! - Acquire loads of release stores join vector clocks (synchronizes-with).
//! - RMWs always read the newest store (modification-order totality).
//! - SeqCst ops couple through one global SC clock, and an SC load never
//!   observes a store older than the newest SC store to that location.
//! - `compare_exchange_weak` never fails spuriously (strict subset of real
//!   behaviors; spurious failures only add retries).
//!
//! Abort discipline: the first failure (race, deadlock, panic, op budget)
//! sets `aborting`; blocked threads unwind via [`Abort`] panics, and every
//! operation reachable from `Drop` glue degrades to a non-scheduling,
//! non-panicking best-effort variant so teardown never double-panics.

use std::collections::HashMap;
use std::panic::panic_any;
use std::sync::atomic::Ordering;
use std::sync::{Condvar, Mutex, MutexGuard};

use crate::clock::{VClock, MAX_THREADS};
use crate::rng::Rng;
use crate::{Config, Failure, FailureKind};

/// Panic payload used to unwind model threads when an execution aborts.
/// Never surfaces to user code: spawn wrappers and the runner catch it.
pub(crate) struct Abort;

/// Store identity for the location-initializing pseudo-store.
const NO_WRITER: usize = usize::MAX;

pub(crate) enum Mode {
    /// Replay `prefix`, then take first-choice (0) everywhere after it.
    Dfs { prefix: Vec<u8> },
    /// Seeded uniform choice at every choice point.
    Random { rng: Rng },
}

#[derive(Clone, Copy, PartialEq, Eq, Debug)]
enum TState {
    Runnable,
    Blocked(&'static str),
    Finished,
}

/// One recorded scheduler/value decision; DFS increments the deepest
/// `chosen` with alternatives left to enumerate the next execution.
pub(crate) struct ChoicePoint {
    pub(crate) chosen: u8,
    pub(crate) options: u8,
}

struct StoreRec {
    val: u64,
    seq: u64,
    clock: VClock,
    writer: usize,
    release: bool,
}

struct Loc {
    stores: Vec<StoreRec>,
    /// Sequence number of the newest SeqCst store to this location.
    sc_seq: u64,
    /// Newest store sequence each thread has observed (read-read coherence).
    last_seen: [u64; MAX_THREADS],
}

impl Loc {
    fn new(init: u64) -> Self {
        Loc {
            stores: vec![StoreRec {
                val: init,
                seq: 0,
                clock: VClock::default(),
                writer: NO_WRITER,
                release: true,
            }],
            sc_seq: 0,
            last_seen: [0; MAX_THREADS],
        }
    }
}

#[derive(Default)]
struct MutexSt {
    holder: Option<usize>,
    waiters: Vec<usize>,
    /// Clock released into the mutex by unlockers, acquired by lockers.
    clock: VClock,
}

pub(crate) struct ExecState {
    mode: Mode,
    pub(crate) record: Vec<ChoicePoint>,
    cursor: usize,
    active: usize,
    threads: Vec<TState>,
    clocks: Vec<VClock>,
    park_token: Vec<bool>,
    park_clock: Vec<VClock>,
    /// joiners[target] = threads blocked joining `target`.
    joiners: Vec<Vec<usize>>,
    preemptions: usize,
    stale_reads: usize,
    pub(crate) failure: Option<Failure>,
    aborting: bool,
    live_os: usize,
    finished: usize,
    cfg: Config,
    locs: HashMap<usize, Loc>,
    mutexes: HashMap<usize, MutexSt>,
    condvars: HashMap<usize, Vec<usize>>,
    /// RaceCell access log: addr -> [(thread, epoch of last access)].
    cells: HashMap<usize, Vec<(usize, u64)>>,
    sc_clock: VClock,
    next_seq: u64,
    ops: u64,
}

fn is_acquire(ord: Ordering) -> bool {
    matches!(ord, Ordering::Acquire | Ordering::AcqRel | Ordering::SeqCst)
}

fn is_release(ord: Ordering) -> bool {
    matches!(ord, Ordering::Release | Ordering::AcqRel | Ordering::SeqCst)
}

impl ExecState {
    fn decide(&mut self, options: u8) -> u8 {
        debug_assert!(options >= 1);
        let mut chosen = if options == 1 {
            0
        } else {
            match &mut self.mode {
                Mode::Dfs { prefix } => {
                    if self.cursor < prefix.len() {
                        prefix[self.cursor]
                    } else {
                        0
                    }
                }
                Mode::Random { rng } => rng.below(options),
            }
        };
        if chosen >= options {
            chosen = options - 1;
        }
        self.cursor += 1;
        self.record.push(ChoicePoint { chosen, options });
        chosen
    }

    fn runnable_except(&self, me: usize) -> Vec<usize> {
        self.threads
            .iter()
            .enumerate()
            .filter(|&(i, t)| i != me && *t == TState::Runnable)
            .map(|(i, _)| i)
            .collect()
    }

    fn schedule_trace(&self) -> Vec<u8> {
        self.record.iter().map(|c| c.chosen).collect()
    }

    fn fail(&mut self, kind: FailureKind, message: String) {
        if self.failure.is_none() {
            self.failure = Some(Failure {
                kind,
                message,
                schedule: self.schedule_trace(),
            });
        }
        self.aborting = true;
    }

    fn deadlock(&mut self) {
        let mut parts = Vec::new();
        for (i, t) in self.threads.iter().enumerate() {
            if let TState::Blocked(why) = t {
                parts.push(format!("t{i}:{why}"));
            }
        }
        self.fail(
            FailureKind::Deadlock,
            format!(
                "deadlock: no runnable thread, blocked = [{}]",
                parts.join(", ")
            ),
        );
    }

    fn sc_sync(&mut self, me: usize) {
        let my = self.clocks[me].clone();
        self.sc_clock.join(&my);
        let sc = self.sc_clock.clone();
        self.clocks[me].join(&sc);
    }

    /// Apply a store of `val` to `addr` by `me` with `ord` semantics.
    fn push_store(&mut self, me: usize, addr: usize, ord: Ordering, val: u64, init: u64) {
        if matches!(ord, Ordering::SeqCst) {
            self.sc_sync(me);
        }
        self.next_seq += 1;
        let seq = self.next_seq;
        let clock = self.clocks[me].clone();
        let depth = self.cfg.stale_depth;
        let release = is_release(ord);
        let loc = self.locs.entry(addr).or_insert_with(|| Loc::new(init));
        loc.stores.push(StoreRec {
            val,
            seq,
            clock,
            writer: me,
            release,
        });
        while loc.stores.len() > depth + 1 {
            loc.stores.remove(0);
        }
        loc.last_seen[me] = seq;
        if matches!(ord, Ordering::SeqCst) {
            loc.sc_seq = seq;
        }
    }

    /// Pick which store a load by `me` observes; stale picks are recorded
    /// choice points so DFS enumerates them like scheduler branches.
    fn do_load(&mut self, me: usize, addr: usize, ord: Ordering, init: u64) -> u64 {
        let sc = matches!(ord, Ordering::SeqCst);
        let stale_ok = self.stale_reads < self.cfg.stale_budget;
        let depth = self.cfg.stale_depth;
        let my_clock = self.clocks[me].clone();
        let cands: Vec<usize> = {
            let loc = self.locs.entry(addr).or_insert_with(|| Loc::new(init));
            let n = loc.stores.len();
            let mut cands = vec![n - 1];
            if stale_ok && depth > 0 {
                let mut k = n - 1;
                while k > 0 && cands.len() <= depth {
                    k -= 1;
                    let s = &loc.stores[k];
                    if s.seq < loc.last_seen[me] {
                        break;
                    }
                    if sc && s.seq < loc.sc_seq {
                        break;
                    }
                    // A newer store that happens-before the reader hides
                    // this one (and everything older).
                    let hidden = loc.stores[k + 1..].iter().any(|s2| {
                        s2.writer != NO_WRITER
                            && my_clock.get(s2.writer) >= s2.clock.get(s2.writer)
                    });
                    if hidden {
                        break;
                    }
                    cands.push(k);
                }
            }
            cands
        };
        let c = self.decide(cands.len() as u8) as usize;
        if c != 0 {
            self.stale_reads += 1;
        }
        let (val, seq, srelease, sclock) = {
            let loc = self.locs.get_mut(&addr).expect("loc exists");
            let s = &loc.stores[cands[c]];
            let out = (s.val, s.seq, s.release, s.clock.clone());
            if out.1 > loc.last_seen[me] {
                loc.last_seen[me] = out.1;
            }
            out
        };
        let _ = seq;
        if is_acquire(ord) && srelease {
            self.clocks[me].join(&sclock);
        }
        if sc {
            self.sc_sync(me);
        }
        val
    }

    /// Peek the newest store (RMWs and failed CAS always read newest).
    fn newest(&mut self, addr: usize, init: u64) -> (u64, bool, VClock, u64) {
        let loc = self.locs.entry(addr).or_insert_with(|| Loc::new(init));
        let s = loc.stores.last().expect("non-empty store history");
        (s.val, s.release, s.clock.clone(), s.seq)
    }

    fn do_rmw(
        &mut self,
        me: usize,
        addr: usize,
        ord: Ordering,
        init: u64,
        new: u64,
    ) -> u64 {
        if matches!(ord, Ordering::SeqCst) {
            self.sc_sync(me);
        }
        let (old, srelease, sclock, _seq) = self.newest(addr, init);
        if is_acquire(ord) && srelease {
            self.clocks[me].join(&sclock);
        }
        self.push_store(me, addr, ord, new, init);
        old
    }
}

pub(crate) struct Exec {
    state: Mutex<ExecState>,
    cv: Condvar,
}

impl Exec {
    pub(crate) fn new(cfg: Config, mode: Mode) -> Self {
        let st = ExecState {
            mode,
            record: Vec::new(),
            cursor: 0,
            active: 0,
            threads: vec![TState::Runnable],
            clocks: vec![VClock::default()],
            park_token: vec![false],
            park_clock: vec![VClock::default()],
            joiners: vec![Vec::new()],
            preemptions: 0,
            stale_reads: 0,
            failure: None,
            aborting: false,
            live_os: 0,
            finished: 0,
            cfg,
            locs: HashMap::new(),
            mutexes: HashMap::new(),
            condvars: HashMap::new(),
            cells: HashMap::new(),
            sc_clock: VClock::default(),
            next_seq: 0,
            ops: 0,
        };
        Exec {
            state: Mutex::new(st),
            cv: Condvar::new(),
        }
    }

    fn lock(&self) -> MutexGuard<'_, ExecState> {
        self.state.lock().unwrap_or_else(|p| p.into_inner())
    }

    fn abort_unwind(&self, st: MutexGuard<'_, ExecState>) -> ! {
        self.cv.notify_all();
        drop(st);
        panic_any(Abort)
    }

    /// Wait until `me` is runnable AND scheduled; unwinds on abort.
    fn wait_turn<'a>(
        &'a self,
        mut st: MutexGuard<'a, ExecState>,
        me: usize,
    ) -> MutexGuard<'a, ExecState> {
        loop {
            if st.aborting {
                self.abort_unwind(st);
            }
            if st.threads[me] == TState::Runnable && st.active == me {
                return st;
            }
            st = self.cv.wait(st).unwrap_or_else(|p| p.into_inner());
        }
    }

    /// Scheduling decision at the start of a visible op. `yielding` ops
    /// (yield_now / spin back-off / sleep) must hand the CPU to another
    /// runnable thread when one exists, so DFS cannot unroll spin loops
    /// into unbounded schedules.
    fn sched<'a>(
        &'a self,
        mut st: MutexGuard<'a, ExecState>,
        me: usize,
        yielding: bool,
    ) -> MutexGuard<'a, ExecState> {
        let others = st.runnable_except(me);
        if others.is_empty() {
            return st;
        }
        if yielding {
            let c = st.decide(others.len() as u8) as usize;
            st.active = others[c];
            self.cv.notify_all();
            return self.wait_turn(st, me);
        }
        if st.preemptions >= st.cfg.preemption_bound {
            return st;
        }
        let c = st.decide((others.len() + 1) as u8) as usize;
        if c > 0 {
            st.preemptions += 1;
            st.active = others[c - 1];
            self.cv.notify_all();
            return self.wait_turn(st, me);
        }
        st
    }

    /// Common op prologue. In aborting mode, returns a degraded guard:
    /// no scheduling, no panics — safe to reach from `Drop` glue while an
    /// `Abort` unwind is in flight.
    fn op_begin(&self, me: usize, yielding: bool) -> MutexGuard<'_, ExecState> {
        let mut st = self.lock();
        if st.aborting {
            return st;
        }
        st.ops += 1;
        if st.ops > st.cfg.max_ops {
            let budget = st.cfg.max_ops;
            st.fail(
                FailureKind::Livelock,
                format!("op budget exceeded ({budget} ops in one execution)"),
            );
            self.abort_unwind(st);
        }
        let mut st = self.sched(st, me, yielding);
        st.clocks[me].bump(me);
        st
    }

    /// Block `me` (already queued on the relevant wait list by the caller),
    /// hand the CPU to some runnable thread, and return once rescheduled.
    fn block<'a>(
        &'a self,
        mut st: MutexGuard<'a, ExecState>,
        me: usize,
        why: &'static str,
    ) -> MutexGuard<'a, ExecState> {
        st.threads[me] = TState::Blocked(why);
        let runnable = st.runnable_except(me);
        if runnable.is_empty() {
            st.deadlock();
            self.abort_unwind(st);
        }
        let c = st.decide(runnable.len() as u8) as usize;
        st.active = runnable[c];
        self.cv.notify_all();
        self.wait_turn(st, me)
    }

    // ----- lifecycle ------------------------------------------------------

    /// Register a child thread (spawn happens-before its first op).
    pub(crate) fn register_thread(&self, me: usize) -> usize {
        let mut st = self.op_begin(me, false);
        let tid = st.threads.len();
        if tid >= MAX_THREADS {
            st.fail(
                FailureKind::Panic,
                format!("model limit: more than {MAX_THREADS} threads per execution"),
            );
            self.abort_unwind(st);
        }
        let mut child = st.clocks[me].clone();
        child.bump(tid);
        st.threads.push(TState::Runnable);
        st.clocks.push(child);
        st.park_token.push(false);
        st.park_clock.push(VClock::default());
        st.joiners.push(Vec::new());
        st.live_os += 1;
        tid
    }

    /// First wait of a freshly spawned OS thread: parked until scheduled.
    pub(crate) fn thread_start(&self, tid: usize) {
        let st = self.lock();
        let _st = self.wait_turn(st, tid);
    }

    /// Called by the spawn wrapper after user code returned or panicked.
    pub(crate) fn finish_thread(&self, tid: usize, panic_msg: Option<String>) {
        let mut st = self.lock();
        st.threads[tid] = TState::Finished;
        st.finished += 1;
        let joiners = std::mem::take(&mut st.joiners[tid]);
        for j in joiners {
            if matches!(st.threads[j], TState::Blocked(_)) {
                st.threads[j] = TState::Runnable;
            }
        }
        if let Some(msg) = panic_msg {
            if !st.aborting {
                st.fail(FailureKind::Panic, format!("thread t{tid} panicked: {msg}"));
            }
        }
        if !st.aborting && st.active == tid {
            let runnable = st.runnable_except(tid);
            if runnable.is_empty() {
                if st.finished < st.threads.len() {
                    st.deadlock();
                }
            } else {
                let c = st.decide(runnable.len() as u8) as usize;
                st.active = runnable[c];
            }
        }
        self.cv.notify_all();
    }

    /// OS thread fully exited (after ctx teardown).
    pub(crate) fn os_exit(&self) {
        let mut st = self.lock();
        st.live_os -= 1;
        self.cv.notify_all();
    }

    /// Record a panic that escaped the runner's closure (main thread).
    pub(crate) fn record_panic_payload(&self, payload: &(dyn std::any::Any + Send)) {
        if payload.is::<Abort>() {
            return;
        }
        let msg = crate::payload_msg(payload);
        let mut st = self.lock();
        if !st.aborting {
            st.fail(FailureKind::Panic, format!("main thread panicked: {msg}"));
        }
        self.cv.notify_all();
    }

    /// Retire the main thread, drive remaining threads to completion, and
    /// wait for every spawned OS thread to exit so state is quiesced.
    pub(crate) fn finish_main_and_wait(&self) {
        let mut st = self.lock();
        st.threads[0] = TState::Finished;
        st.finished += 1;
        for j in std::mem::take(&mut st.joiners[0]) {
            if matches!(st.threads[j], TState::Blocked(_)) {
                st.threads[j] = TState::Runnable;
            }
        }
        loop {
            if st.finished >= st.threads.len() {
                break;
            }
            if !st.aborting {
                let runnable: Vec<usize> = st
                    .threads
                    .iter()
                    .enumerate()
                    .filter(|&(_, t)| *t == TState::Runnable)
                    .map(|(i, _)| i)
                    .collect();
                if runnable.is_empty() {
                    st.deadlock();
                } else if st.threads[st.active] != TState::Runnable {
                    let c = st.decide(runnable.len() as u8) as usize;
                    st.active = runnable[c];
                }
            }
            self.cv.notify_all();
            st = self.cv.wait(st).unwrap_or_else(|p| p.into_inner());
        }
        while st.live_os > 0 {
            st = self.cv.wait(st).unwrap_or_else(|p| p.into_inner());
        }
    }

    /// Extract the recorded schedule and failure after the run quiesced.
    pub(crate) fn outcome(&self) -> (Vec<ChoicePoint>, Option<Failure>) {
        let mut st = self.lock();
        (std::mem::take(&mut st.record), st.failure.take())
    }

    // ----- atomics --------------------------------------------------------

    pub(crate) fn atomic_load(&self, me: usize, addr: usize, ord: Ordering, init: u64) -> u64 {
        let mut st = self.op_begin(me, false);
        st.do_load(me, addr, ord, init)
    }

    pub(crate) fn atomic_store(
        &self,
        me: usize,
        addr: usize,
        ord: Ordering,
        val: u64,
        init: u64,
        mirror: impl FnOnce(u64),
    ) {
        let mut st = self.op_begin(me, false);
        st.push_store(me, addr, ord, val, init);
        // Mirror the model's newest value into the real atomic while the
        // state lock serializes us, so `get_mut` after the execution (and
        // location init on first touch) observe the model's final value.
        mirror(val);
        drop(st);
    }

    /// `new = f(old)` computed by the caller from the newest value read
    /// under this same lock acquisition via the `compute` closure.
    pub(crate) fn atomic_rmw(
        &self,
        me: usize,
        addr: usize,
        ord: Ordering,
        init: u64,
        compute: impl FnOnce(u64) -> u64,
        mirror: impl FnOnce(u64),
    ) -> u64 {
        let mut st = self.op_begin(me, false);
        let (old, _, _, _) = st.newest(addr, init);
        let new = compute(old);
        let old2 = st.do_rmw(me, addr, ord, init, new);
        debug_assert_eq!(old, old2);
        mirror(new);
        drop(st);
        old
    }

    pub(crate) fn atomic_cas(
        &self,
        me: usize,
        addr: usize,
        expect: u64,
        new: u64,
        ok: Ordering,
        err: Ordering,
        init: u64,
        mirror: impl FnOnce(u64),
    ) -> Result<u64, u64> {
        let mut st = self.op_begin(me, false);
        let (cur, srelease, sclock, seq) = st.newest(addr, init);
        if cur == expect {
            let old = st.do_rmw(me, addr, ok, init, new);
            debug_assert_eq!(old, cur);
            mirror(new);
            Ok(cur)
        } else {
            // Failed CAS is a load of the newest value with `err` ordering.
            if is_acquire(err) && srelease {
                st.clocks[me].join(&sclock);
            }
            if matches!(err, Ordering::SeqCst) {
                st.sc_sync(me);
            }
            let loc = st.locs.get_mut(&addr).expect("loc exists");
            if seq > loc.last_seen[me] {
                loc.last_seen[me] = seq;
            }
            Err(cur)
        }
    }

    pub(crate) fn fence(&self, me: usize, ord: Ordering) {
        let mut st = self.op_begin(me, false);
        if matches!(ord, Ordering::SeqCst) {
            st.sc_sync(me);
        }
        drop(st);
    }

    // ----- race cells -----------------------------------------------------

    /// Record an access to a plain (non-atomic) shared cell; flags a data
    /// race — and aborts *before* the racing access executes — when a prior
    /// access by another thread is not ordered before this one.
    pub(crate) fn cell_access(&self, me: usize, addr: usize) {
        let mut st = self.op_begin(me, false);
        if st.aborting {
            return;
        }
        let my = st.clocks[me].clone();
        let mut race_with: Option<usize> = None;
        if let Some(entries) = st.cells.get(&addr) {
            for &(t, epoch) in entries {
                if t != me && my.get(t) < epoch {
                    race_with = Some(t);
                    break;
                }
            }
        }
        if let Some(t) = race_with {
            st.fail(
                FailureKind::DataRace,
                format!(
                    "data race on cell {addr:#x}: t{me} accesses without \
                     happens-before ordering after t{t}'s access"
                ),
            );
            self.abort_unwind(st);
        }
        let epoch = my.get(me);
        let entries = st.cells.entry(addr).or_default();
        entries.retain(|&(t, _)| t != me);
        entries.push((me, epoch));
    }

    // ----- mutex / condvar ------------------------------------------------

    pub(crate) fn mutex_lock(&self, me: usize, addr: usize) {
        let mut st = self.op_begin(me, false);
        loop {
            if st.aborting {
                // Degraded teardown: force-take so Drop-glue never hangs.
                st.mutexes.entry(addr).or_default().holder = Some(me);
                return;
            }
            let grabbed = {
                let m = st.mutexes.entry(addr).or_default();
                if m.holder.is_none() {
                    m.holder = Some(me);
                    Some(m.clock.clone())
                } else {
                    if !m.waiters.contains(&me) {
                        m.waiters.push(me);
                    }
                    None
                }
            };
            match grabbed {
                Some(c) => {
                    st.clocks[me].join(&c);
                    return;
                }
                None => st = self.block(st, me, "mutex"),
            }
        }
    }

    pub(crate) fn mutex_unlock(&self, me: usize, addr: usize) {
        let mut st = self.op_begin(me, false);
        let my = st.clocks[me].clone();
        let wake = {
            let m = st.mutexes.entry(addr).or_default();
            m.holder = None;
            m.clock.join(&my);
            std::mem::take(&mut m.waiters)
        };
        for w in wake {
            if matches!(st.threads[w], TState::Blocked(_)) {
                st.threads[w] = TState::Runnable;
            }
        }
    }

    /// Atomically (w.r.t. the model) release the mutex, register on the
    /// condvar, block until notified, then re-acquire the mutex.
    pub(crate) fn condvar_wait(&self, me: usize, cv_addr: usize, mx_addr: usize) {
        {
            let mut st = self.op_begin(me, false);
            if st.aborting {
                return; // spurious wakeup; legal for condvars
            }
            let my = st.clocks[me].clone();
            let wake = {
                let m = st.mutexes.entry(mx_addr).or_default();
                m.holder = None;
                m.clock.join(&my);
                std::mem::take(&mut m.waiters)
            };
            for w in wake {
                if matches!(st.threads[w], TState::Blocked(_)) {
                    st.threads[w] = TState::Runnable;
                }
            }
            st.condvars.entry(cv_addr).or_default().push(me);
            let _st = self.block(st, me, "condvar");
        }
        self.mutex_lock(me, mx_addr);
    }

    pub(crate) fn condvar_notify(&self, me: Option<usize>, cv_addr: usize, all: bool) {
        let mut st = match me {
            Some(me) => self.op_begin(me, false),
            None => self.lock(),
        };
        let woken: Vec<usize> = {
            let list = st.condvars.entry(cv_addr).or_default();
            if all {
                std::mem::take(list)
            } else if list.is_empty() {
                Vec::new()
            } else {
                vec![list.remove(0)]
            }
        };
        for w in woken {
            if matches!(st.threads[w], TState::Blocked(_)) {
                st.threads[w] = TState::Runnable;
            }
        }
    }

    // ----- park / unpark / join / yield ----------------------------------

    pub(crate) fn park(&self, me: usize) {
        let mut st = self.op_begin(me, false);
        loop {
            if st.aborting {
                return; // spurious wakeup; park permits them
            }
            if st.park_token[me] {
                st.park_token[me] = false;
                let c = st.park_clock[me].clone();
                st.clocks[me].join(&c);
                return;
            }
            st = self.block(st, me, "park");
        }
    }

    pub(crate) fn unpark(&self, me: Option<usize>, target: usize) {
        let mut st = match me {
            Some(me) => self.op_begin(me, false),
            None => self.lock(),
        };
        st.park_token[target] = true;
        if let Some(me) = me {
            let my = st.clocks[me].clone();
            st.park_clock[target].join(&my);
        }
        if matches!(st.threads[target], TState::Blocked("park")) {
            st.threads[target] = TState::Runnable;
        }
        self.cv.notify_all();
    }

    pub(crate) fn join_block(&self, me: usize, target: usize) {
        let mut st = self.op_begin(me, false);
        loop {
            if st.aborting {
                return; // fall through to the OS join; the target unwinds
            }
            if matches!(st.threads[target], TState::Finished) {
                // Join synchronizes-with everything the target did.
                let c = st.clocks[target].clone();
                st.clocks[me].join(&c);
                return;
            }
            st.joiners[target].push(me);
            st = self.block(st, me, "join");
        }
    }

    pub(crate) fn yield_op(&self, me: usize) {
        let _st = self.op_begin(me, true);
    }
}

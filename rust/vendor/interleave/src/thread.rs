//! Model-aware thread operations, API-compatible with `std::thread` for
//! the subset the serving path uses: `spawn`, `JoinHandle::join`,
//! `current`, `Thread::unpark`, `park`, `park_timeout`, `yield_now`,
//! `sleep`.
//!
//! In pass-through mode everything delegates to `std`. Under a model
//! execution, spawned threads are real OS threads registered with the
//! scheduler: they start parked, run only when scheduled, and report their
//! completion (or panic) back to the execution. `park_timeout` behaves as
//! `park` — a passing model proves the wakeup protocol correct without its
//! backstop timeouts — and `sleep`/`yield_now` are pure scheduling points.

use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::Arc;
use std::time::Duration;

use crate::ctx;
use crate::exec::{Abort, Exec};

pub struct JoinHandle<T> {
    os: Option<std::thread::JoinHandle<std::thread::Result<T>>>,
    model: Option<(Arc<Exec>, usize)>,
}

impl<T> JoinHandle<T> {
    pub fn join(mut self) -> std::thread::Result<T> {
        if let Some((exec, tid)) = self.model.take() {
            if let Some(c) = ctx::current() {
                if Arc::ptr_eq(&exec, &c.exec) {
                    exec.join_block(c.tid, tid);
                }
            }
        }
        match self.os.take().expect("join consumes the handle").join() {
            Ok(r) => r,
            Err(p) => Err(p),
        }
    }

    pub fn is_finished(&self) -> bool {
        self.os
            .as_ref()
            .map(|h| h.is_finished())
            .unwrap_or(true)
    }
}

pub fn spawn<F, T>(f: F) -> JoinHandle<T>
where
    F: FnOnce() -> T + Send + 'static,
    T: Send + 'static,
{
    match ctx::current() {
        None => {
            let os = std::thread::spawn(move || catch_unwind(AssertUnwindSafe(f)));
            JoinHandle {
                os: Some(os),
                model: None,
            }
        }
        Some(c) => {
            let tid = c.exec.register_thread(c.tid);
            let exec = c.exec.clone();
            let os = std::thread::spawn(move || {
                ctx::set(Some(ctx::Ctx {
                    exec: exec.clone(),
                    tid,
                }));
                let r = catch_unwind(AssertUnwindSafe(|| {
                    // Parked until the scheduler picks this thread first.
                    exec.thread_start(tid);
                    f()
                }));
                let panic_msg = match &r {
                    Ok(_) => None,
                    Err(p) if p.is::<Abort>() => None,
                    Err(p) => Some(crate::payload_msg(p.as_ref())),
                };
                exec.finish_thread(tid, panic_msg);
                ctx::set(None);
                exec.os_exit();
                r
            });
            JoinHandle {
                os: Some(os),
                model: Some((c.exec.clone(), tid)),
            }
        }
    }
}

#[derive(Clone)]
enum Kind {
    Os(std::thread::Thread),
    Model { exec: Arc<Exec>, tid: usize },
}

/// Handle to a thread, as returned by [`current`]; supports `unpark`.
#[derive(Clone)]
pub struct Thread {
    kind: Kind,
}

impl Thread {
    pub fn unpark(&self) {
        match &self.kind {
            Kind::Os(t) => t.unpark(),
            Kind::Model { exec, tid } => {
                let me = ctx::current()
                    .and_then(|c| Arc::ptr_eq(&c.exec, exec).then_some(c.tid));
                exec.unpark(me, *tid);
            }
        }
    }
}

impl std::fmt::Debug for Thread {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match &self.kind {
            Kind::Os(t) => write!(f, "{t:?}"),
            Kind::Model { tid, .. } => write!(f, "ModelThread(t{tid})"),
        }
    }
}

pub fn current() -> Thread {
    match ctx::current() {
        Some(c) => Thread {
            kind: Kind::Model {
                exec: c.exec,
                tid: c.tid,
            },
        },
        None => Thread {
            kind: Kind::Os(std::thread::current()),
        },
    }
}

pub fn park() {
    match ctx::current() {
        Some(c) => c.exec.park(c.tid),
        None => std::thread::park(),
    }
}

/// Under the model this is `park` without the timeout: the model proves
/// the protocol correct without its belt-and-braces backstops.
pub fn park_timeout(dur: Duration) {
    match ctx::current() {
        Some(c) => c.exec.park(c.tid),
        None => std::thread::park_timeout(dur),
    }
}

pub fn yield_now() {
    match ctx::current() {
        Some(c) => c.exec.yield_op(c.tid),
        None => std::thread::yield_now(),
    }
}

/// Under the model, sleeping is just a yield: time does not pass.
pub fn sleep(dur: Duration) {
    match ctx::current() {
        Some(c) => c.exec.yield_op(c.tid),
        None => std::thread::sleep(dur),
    }
}

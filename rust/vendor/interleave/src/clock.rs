//! Vector clocks over a fixed thread universe.
//!
//! The checker serializes at most [`MAX_THREADS`] model threads per
//! execution, so a clock is a flat array — no allocation, cheap joins.

/// Maximum model threads per execution (including the main/runner thread).
pub const MAX_THREADS: usize = 8;

/// A vector clock: component `i` is the last observed tick of thread `i`.
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub(crate) struct VClock([u64; MAX_THREADS]);

impl VClock {
    pub(crate) fn get(&self, i: usize) -> u64 {
        self.0[i]
    }

    pub(crate) fn bump(&mut self, i: usize) {
        self.0[i] += 1;
    }

    /// Pointwise max: after `a.join(b)`, everything `b` has observed is
    /// also observed by `a` (the happens-before edge of an acquire).
    pub(crate) fn join(&mut self, other: &VClock) {
        for i in 0..MAX_THREADS {
            if other.0[i] > self.0[i] {
                self.0[i] = other.0[i];
            }
        }
    }
}

//! Model-aware `Mutex` and `Condvar`, API-compatible with `std::sync`
//! for sized contents (all the serving path needs).
//!
//! Pass-through mode delegates to the embedded `std` primitives. Under a
//! model execution the *model* mutex (keyed by address, serialized by the
//! scheduler) is what orders threads; the real `std::sync::Mutex` is still
//! locked around data access so the contents stay memory-safe even if the
//! model has a bug, but it can never contend: the model admits one holder
//! at a time, and the real lock is always released before the model lock.
//!
//! `Condvar::wait` atomically (w.r.t. the model) releases the mutex and
//! registers as a waiter, so genuine lost-wakeup bugs in *user* code are
//! still observable as model deadlocks while the primitive itself cannot
//! drop notifications. `wait_timeout` never times out under the model: a
//! passing model proves the protocol sound without its timeout backstops.
//!
//! Model mutexes are keyed by address: keep them at a stable address for
//! the duration of an execution (the serving path owns them via `Arc`).

use std::mem::ManuallyDrop;
use std::ops::{Deref, DerefMut};
use std::sync::{LockResult, PoisonError};
use std::time::Duration;

use crate::ctx;

pub struct Mutex<T> {
    inner: std::sync::Mutex<T>,
}

pub struct MutexGuard<'a, T: 'a> {
    /// Real guard; dropped manually so Condvar can take it without running
    /// our model-unlock Drop glue.
    std_g: ManuallyDrop<std::sync::MutexGuard<'a, T>>,
    owner: &'a Mutex<T>,
    model: bool,
}

impl<T> Mutex<T> {
    pub const fn new(t: T) -> Self {
        Mutex {
            inner: std::sync::Mutex::new(t),
        }
    }

    fn addr(&self) -> usize {
        &self.inner as *const _ as usize
    }

    pub fn lock(&self) -> LockResult<MutexGuard<'_, T>> {
        match ctx::current() {
            Some(c) => {
                c.exec.mutex_lock(c.tid, self.addr());
                // The model admitted us; the real lock is uncontended.
                let g = self.inner.lock().unwrap_or_else(|p| p.into_inner());
                Ok(MutexGuard {
                    std_g: ManuallyDrop::new(g),
                    owner: self,
                    model: true,
                })
            }
            None => match self.inner.lock() {
                Ok(g) => Ok(MutexGuard {
                    std_g: ManuallyDrop::new(g),
                    owner: self,
                    model: false,
                }),
                Err(p) => Err(PoisonError::new(MutexGuard {
                    std_g: ManuallyDrop::new(p.into_inner()),
                    owner: self,
                    model: false,
                })),
            },
        }
    }

    pub fn get_mut(&mut self) -> LockResult<&mut T> {
        self.inner.get_mut()
    }

    pub fn into_inner(self) -> LockResult<T> {
        self.inner.into_inner()
    }
}

impl<T: Default> Default for Mutex<T> {
    fn default() -> Self {
        Mutex::new(T::default())
    }
}

impl<T: std::fmt::Debug> std::fmt::Debug for Mutex<T> {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{:?}", self.inner)
    }
}

impl<T> Deref for MutexGuard<'_, T> {
    type Target = T;
    fn deref(&self) -> &T {
        &self.std_g
    }
}

impl<T> DerefMut for MutexGuard<'_, T> {
    fn deref_mut(&mut self) -> &mut T {
        &mut self.std_g
    }
}

impl<T> Drop for MutexGuard<'_, T> {
    fn drop(&mut self) {
        // Release the real lock first so the next model holder can take it
        // without contention, then release the model lock.
        // SAFETY: std_g is dropped exactly once: here, or never — Condvar
        // disassembles the guard via `forget` before this Drop could run.
        unsafe { ManuallyDrop::drop(&mut self.std_g) };
        if self.model {
            if let Some(c) = ctx::current() {
                c.exec.mutex_unlock(c.tid, self.owner.addr());
            }
        }
    }
}

/// Mirrors `std::sync::WaitTimeoutResult`; under the model it never
/// reports a timeout (waits are genuine blocking waits).
#[derive(Clone, Copy, Debug)]
pub struct WaitTimeoutResult(bool);

impl WaitTimeoutResult {
    pub fn timed_out(&self) -> bool {
        self.0
    }
}

pub struct Condvar {
    inner: std::sync::Condvar,
}

impl Condvar {
    pub const fn new() -> Self {
        Condvar {
            inner: std::sync::Condvar::new(),
        }
    }

    fn addr(&self) -> usize {
        &self.inner as *const _ as usize
    }

    /// Take the real guard out of `guard` without running the model-unlock
    /// Drop glue, returning it and the owning mutex.
    fn disassemble<'a, T>(
        mut guard: MutexGuard<'a, T>,
    ) -> (std::sync::MutexGuard<'a, T>, &'a Mutex<T>) {
        // SAFETY: guard is forgotten right after, so std_g is taken exactly
        // once and MutexGuard::drop never runs on it.
        let std_g = unsafe { ManuallyDrop::take(&mut guard.std_g) };
        let owner = guard.owner;
        std::mem::forget(guard);
        (std_g, owner)
    }

    pub fn wait<'a, T>(&self, guard: MutexGuard<'a, T>) -> LockResult<MutexGuard<'a, T>> {
        match ctx::current() {
            Some(c) => {
                let (std_g, owner) = Self::disassemble(guard);
                // Drop the real lock before blocking in the model; the
                // model release + waiter registration happen atomically
                // inside condvar_wait, so no notify can slip between them.
                drop(std_g);
                c.exec.condvar_wait(c.tid, self.addr(), owner.addr());
                let g = owner.inner.lock().unwrap_or_else(|p| p.into_inner());
                Ok(MutexGuard {
                    std_g: ManuallyDrop::new(g),
                    owner,
                    model: true,
                })
            }
            None => {
                let (std_g, owner) = Self::disassemble(guard);
                match self.inner.wait(std_g) {
                    Ok(g) => Ok(MutexGuard {
                        std_g: ManuallyDrop::new(g),
                        owner,
                        model: false,
                    }),
                    Err(p) => Err(PoisonError::new(MutexGuard {
                        std_g: ManuallyDrop::new(p.into_inner()),
                        owner,
                        model: false,
                    })),
                }
            }
        }
    }

    pub fn wait_timeout<'a, T>(
        &self,
        guard: MutexGuard<'a, T>,
        dur: Duration,
    ) -> LockResult<(MutexGuard<'a, T>, WaitTimeoutResult)> {
        match ctx::current() {
            Some(_) => {
                // Under the model the backstop never fires: the protocol
                // must be wakeup-correct on its own or the checker reports
                // a deadlock.
                match self.wait(guard) {
                    Ok(g) => Ok((g, WaitTimeoutResult(false))),
                    Err(p) => Err(PoisonError::new((p.into_inner(), WaitTimeoutResult(false)))),
                }
            }
            None => {
                let (std_g, owner) = Self::disassemble(guard);
                match self.inner.wait_timeout(std_g, dur) {
                    Ok((g, t)) => Ok((
                        MutexGuard {
                            std_g: ManuallyDrop::new(g),
                            owner,
                            model: false,
                        },
                        WaitTimeoutResult(t.timed_out()),
                    )),
                    Err(p) => {
                        let (g, t) = p.into_inner();
                        Err(PoisonError::new((
                            MutexGuard {
                                std_g: ManuallyDrop::new(g),
                                owner,
                                model: false,
                            },
                            WaitTimeoutResult(t.timed_out()),
                        )))
                    }
                }
            }
        }
    }

    pub fn notify_one(&self) {
        match ctx::current() {
            Some(c) => c.exec.condvar_notify(Some(c.tid), self.addr(), false),
            None => self.inner.notify_one(),
        }
    }

    pub fn notify_all(&self) {
        match ctx::current() {
            Some(c) => c.exec.condvar_notify(Some(c.tid), self.addr(), true),
            None => self.inner.notify_all(),
        }
    }
}

impl Default for Condvar {
    fn default() -> Self {
        Condvar::new()
    }
}

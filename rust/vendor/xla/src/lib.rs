//! Offline stub of the `xla` (PJRT) bindings.
//!
//! The real crate wraps `xla_extension` and needs a multi-GB native build
//! that is not available in this offline image.  This stub mirrors exactly
//! the API surface `crate::runtime` and the coordinator workers use, so the
//! whole serving layer *compiles* unchanged; every entry point returns an
//! error at run time and the callers' existing `anyhow` error paths report
//! it cleanly (e.g. `a100win serve` prints "PJRT is unavailable...").
//!
//! Swap this path dependency for the real `xla` crate (and enable the
//! `pjrt` cargo feature to un-gate the artifact integration tests) on a
//! machine with the native toolchain.

/// Error type.  Callers format it with `{:?}` or convert via `?` into
/// `anyhow::Error` (which needs the `std::error::Error` impl).
#[derive(Debug, Clone)]
pub struct Error(pub String);

impl std::fmt::Display for Error {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(&self.0)
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

const UNAVAILABLE: &str =
    "PJRT is unavailable in this offline build (stub vendor/xla crate); \
     link the real xla crate to execute AOT artifacts";

fn unavailable<T>() -> Result<T> {
    Err(Error(UNAVAILABLE.to_string()))
}

/// Stub of a PJRT client handle.
pub struct PjRtClient(());

impl PjRtClient {
    pub fn cpu() -> Result<Self> {
        unavailable()
    }

    pub fn platform_name(&self) -> String {
        "stub".to_string()
    }

    pub fn compile(&self, _computation: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        unavailable()
    }

    pub fn buffer_from_host_buffer<T: Copy>(
        &self,
        _data: &[T],
        _dims: &[usize],
        _device: Option<usize>,
    ) -> Result<PjRtBuffer> {
        unavailable()
    }
}

/// Stub of a device buffer handle.
pub struct PjRtBuffer(());

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        unavailable()
    }
}

/// Stub of a compiled executable handle.
pub struct PjRtLoadedExecutable(());

impl PjRtLoadedExecutable {
    pub fn execute_b(&self, _args: &[&PjRtBuffer]) -> Result<Vec<Vec<PjRtBuffer>>> {
        unavailable()
    }
}

/// Stub of a host literal.
pub struct Literal(());

impl Literal {
    pub fn to_tuple(self) -> Result<Vec<Literal>> {
        unavailable()
    }

    pub fn to_vec<T>(&self) -> Result<Vec<T>> {
        unavailable()
    }
}

/// Stub of a parsed HLO module proto.
pub struct HloModuleProto(());

impl HloModuleProto {
    pub fn from_text_file(_path: &str) -> Result<Self> {
        unavailable()
    }
}

/// Stub of an XLA computation.
pub struct XlaComputation(());

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> Self {
        Self(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn every_entry_point_reports_unavailable() {
        let err = PjRtClient::cpu().err().unwrap();
        assert!(format!("{err:?}").contains("PJRT is unavailable"));
        assert!(HloModuleProto::from_text_file("/tmp/x").is_err());
    }
}

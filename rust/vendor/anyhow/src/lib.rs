//! Offline in-tree substitute for the `anyhow` crate.
//!
//! This build runs with no network registry, so the subset of anyhow the
//! project actually uses is implemented here: [`Error`] (a context chain of
//! messages), [`Result`], the [`anyhow!`] / [`bail!`] / [`ensure!`] macros,
//! and the [`Context`] extension trait.  Semantics mirror real anyhow where
//! it matters to callers:
//!
//! * `{}` displays the outermost message, `{:#}` the whole chain joined
//!   with `": "`, and `{:?}` the outermost message plus a `Caused by:`
//!   list — the three formats the CLI and server error paths rely on.
//! * `?` converts any `std::error::Error + Send + Sync + 'static` via a
//!   blanket `From`, capturing its `source()` chain.
//! * `Error` deliberately does **not** implement `std::error::Error`, so
//!   the blanket `From` cannot overlap the identity conversion (the same
//!   trick real anyhow uses).

use std::fmt;

/// A context-chained error.  `chain[0]` is the outermost message, the last
/// element is the root cause.
pub struct Error {
    chain: Vec<String>,
}

impl Error {
    /// Build an error from any displayable message.
    pub fn msg<M: fmt::Display>(m: M) -> Self {
        Self {
            chain: vec![m.to_string()],
        }
    }

    /// Wrap with an outer context message.
    pub fn context<C: fmt::Display>(mut self, c: C) -> Self {
        self.chain.insert(0, c.to_string());
        self
    }

    /// The root-cause message (innermost).
    pub fn root_cause(&self) -> &str {
        self.chain.last().map(String::as_str).unwrap_or("")
    }

    /// Context messages, outermost first.
    pub fn chain(&self) -> impl Iterator<Item = &str> {
        self.chain.iter().map(String::as_str)
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            // `{:#}`: the full chain, outermost first.
            f.write_str(&self.chain.join(": "))
        } else {
            f.write_str(self.chain.first().map(String::as_str).unwrap_or(""))
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.chain.first().map(String::as_str).unwrap_or(""))?;
        if self.chain.len() > 1 {
            write!(f, "\n\nCaused by:")?;
            for cause in &self.chain[1..] {
                write!(f, "\n    {cause}")?;
            }
        }
        Ok(())
    }
}

impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Self {
        let mut chain = vec![e.to_string()];
        let mut src = e.source();
        while let Some(s) = src {
            chain.push(s.to_string());
            src = s.source();
        }
        Self { chain }
    }
}

/// `anyhow::Result<T>` with the usual defaultable error parameter.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Extension trait adding context to fallible results.
pub trait Context<T> {
    fn context<C: fmt::Display>(self, c: C) -> Result<T>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E: Into<Error>> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, c: C) -> Result<T> {
        self.map_err(|e| e.into().context(c))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| e.into().context(f()))
    }
}

/// Construct an [`Error`] from a format string or any displayable value.
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(format!($msg))
    };
    ($fmt:literal, $($arg:tt)*) => {
        $crate::Error::msg(format!($fmt, $($arg)*))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg($err)
    };
}

/// Early-return with an error built like [`anyhow!`].
#[macro_export]
macro_rules! bail {
    ($($t:tt)*) => {
        return Err($crate::anyhow!($($t)*))
    };
}

/// Assert a condition, early-returning an error when it fails.
#[macro_export]
macro_rules! ensure {
    ($cond:expr $(,)?) => {
        if !($cond) {
            return Err($crate::anyhow!("condition failed: `{}`", stringify!($cond)));
        }
    };
    ($cond:expr, $($t:tt)*) => {
        if !($cond) {
            return Err($crate::anyhow!($($t)*));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "no such file")
    }

    #[test]
    fn display_and_alternate() {
        let e: Error = Err::<(), _>(io_err())
            .context("reading manifest")
            .unwrap_err();
        assert_eq!(format!("{e}"), "reading manifest");
        assert_eq!(format!("{e:#}"), "reading manifest: no such file");
    }

    #[test]
    fn debug_lists_causes() {
        let e = anyhow!("root").context("outer");
        let dbg = format!("{e:?}");
        assert!(dbg.starts_with("outer"));
        assert!(dbg.contains("Caused by:"));
        assert!(dbg.contains("root"));
    }

    #[test]
    fn macros_build_messages() {
        let x = 3;
        let e = anyhow!("value {x} bad");
        assert_eq!(format!("{e}"), "value 3 bad");
        let e = anyhow!("pair {} {}", 1, 2);
        assert_eq!(format!("{e}"), "pair 1 2");
        let e = anyhow!(String::from("owned"));
        assert_eq!(format!("{e}"), "owned");
    }

    #[test]
    fn bail_and_ensure() {
        fn f(ok: bool) -> Result<u32> {
            ensure!(ok, "must be ok");
            if !ok {
                bail!("unreachable");
            }
            Ok(7)
        }
        assert_eq!(f(true).unwrap(), 7);
        assert_eq!(format!("{}", f(false).unwrap_err()), "must be ok");

        fn g(n: usize) -> Result<()> {
            ensure!(n > 2);
            Ok(())
        }
        assert!(g(3).is_ok());
        assert!(format!("{}", g(0).unwrap_err()).contains("n > 2"));
    }

    #[test]
    fn question_mark_converts_std_errors() {
        fn f() -> Result<()> {
            let _ = "abc".parse::<u64>()?;
            Ok(())
        }
        assert!(f().is_err());
    }

    #[test]
    fn with_context_lazily_formats() {
        let r: Result<()> = Err(io_err()).with_context(|| format!("worker {}", 4));
        assert_eq!(format!("{}", r.unwrap_err()), "worker 4");
    }
}

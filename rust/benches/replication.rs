//! Bench: hot-window read replication (the replicate lever, the fifth
//! rung of the fleet ladder) against migration-only repartitioning.
//!
//! The fleet is three equal simulated cards; under zipf(1.1) one shard
//! owns nearly every access, and no boundary migration can shed it — the
//! hottest rows sit at the *start* of shard 0, and moving the boundary
//! only sheds its cold tail.  Replication puts zero-copy read replicas of
//! the hot shard on the other cards and routes over them with
//! power-of-two-choices on live queue depth, so every card's bandwidth
//! serves the hotspot.  Arms:
//!
//! * **migration-only** — the four-rung ladder (`max_lever: Migrate`).
//! * **replicated** — the same fleet with [`ReplicateConfig`] armed
//!   (`capacity_fraction: 0.0`: manual epochs measure wall-clock demand
//!   against *simulated* bandwidth, which no open loop can meet).
//!
//! Scored on fleet makespan GB/s (units run in parallel; the slowest
//! bounds the fleet) with the per-device aggregate reported alongside.
//! After the zipf measurement the replicated arm's load turns uniform and
//! the bench audits the subside path: every replica must retire (the
//! exit-share check), witnessed in the decision trace.
//!
//! Emits `BENCH_replication.json` (crate dir under `cargo bench`).  Flags
//! (after `--`): `--smoke` shrinks the sweep for CI and skips the
//! assertions (the full run asserts replicated >= 1.4x migration-only
//! under zipf and drift, parity within 5% under uniform, and zero live
//! replicas after the subside).

use std::sync::Arc;

use a100win::coordinator::{
    AdaptiveConfig, BatcherConfig, CardSpec, ControlPlaneConfig, Lever, ReplicateConfig, Table,
};
use a100win::probe::TopologyMap;
use a100win::service::{FleetConfig, FleetService, RebalanceConfig, SimTiming};
use a100win::util::json::Json;
use a100win::workload::{synth::Distribution, RequestGen, WorkloadSpec};

const D: usize = 32;
const ROW_BYTES: u64 = (D * 4) as u64; // 128 B, the paper's cache line
const CARDS: usize = 3;
const ROWS: u64 = 16_384;
const ROWS_PER_REQUEST: usize = 512;

fn map(card: usize) -> TopologyMap {
    TopologyMap {
        groups: vec![vec![0, 1], vec![2, 3]],
        reach_bytes: 64 << 30,
        solo_gbps: vec![100.0, 100.0],
        independent: true,
        card_id: format!("replication-card{card}"),
    }
}

/// Every card can host a whole-table replica on top of its own shard.
fn card(i: usize) -> CardSpec {
    CardSpec {
        map: map(i),
        memory_bytes: ROWS * ROW_BYTES,
    }
}

fn quick_batcher() -> BatcherConfig {
    BatcherConfig {
        max_batch_rows: 8_192,
        max_wait: std::time::Duration::from_micros(200),
        max_pending: 4_096,
    }
}

fn build_fleet(table: &Table, replicate: bool) -> FleetService {
    FleetService::build_sim_with(
        (0..CARDS).map(|i| (card(i), SimTiming::Probed)).collect(),
        table,
        FleetConfig {
            batcher: quick_batcher(),
            seed: 7,
            adaptive: Some(AdaptiveConfig::default()),
            rebalance: RebalanceConfig {
                min_imbalance: 0.15,
                min_epoch_rows: 512,
                min_move_rows: 16,
            },
            // Eager escalation for manual epochs: the ladder walks
            // redeal -> resplit -> migrate -> repack -> replicate in a
            // handful of failing epochs instead of minutes of patience.
            control: ControlPlaneConfig {
                min_imbalance: 0.10,
                patience: 1,
                cooldown: 0,
                max_lever: Lever::Migrate, // raised to Replicate when armed
                trace_len: 512,
            },
            replicate: replicate.then(|| ReplicateConfig {
                capacity_fraction: 0.0,
                ..ReplicateConfig::default()
            }),
            ..FleetConfig::default()
        },
    )
    .expect("start sim fleet")
}

fn spec(dist: Distribution) -> WorkloadSpec {
    WorkloadSpec {
        total_rows: ROWS,
        distribution: dist,
        request_rows: (ROWS_PER_REQUEST, ROWS_PER_REQUEST),
        seed: 99,
    }
}

fn verify(out: &[f32], rows: &[u64], table: &Table) {
    assert_eq!(out.len(), rows.len() * D, "short response");
    for (k, &row) in rows.iter().enumerate() {
        for j in 0..D {
            assert_eq!(out[k * D + j], table.expected(row, j), "row {row} col {j}");
        }
    }
}

struct ArmResult {
    makespan_gbps: f64,
    aggregate_gbps: f64,
    replicas_created: u64,
    replicas_live: usize,
}

/// Drive `warm` convergence requests (control epoch after each, so the
/// ladder can escalate and publish), reset the simulated accounting, then
/// drive `measured` requests and score the measured phase.
fn run_arm(
    fleet: &FleetService,
    table: &Table,
    gen: &mut RequestGen,
    warm: usize,
    measured: usize,
) -> ArmResult {
    for _ in 0..warm {
        let rows = Arc::new(gen.next_request());
        let out = fleet.lookup(Arc::clone(&rows)).expect("lookup");
        fleet.recycle(out);
        fleet.control_epoch();
    }
    fleet.reset_sim_stats();
    for i in 0..measured {
        let rows = Arc::new(gen.next_request());
        let out = fleet.lookup(Arc::clone(&rows)).expect("lookup");
        if i % 64 == 0 {
            verify(&out, &rows, table);
        }
        fleet.recycle(out);
        // Keep epochs ticking so drift arms can re-replicate (and the
        // subsided ones de-replicate) mid-measurement.
        fleet.control_epoch();
        fleet
            .replica_set()
            .check(&fleet.plan(), CARDS)
            .expect("published replica set violates invariants");
    }
    let m = fleet.fleet_metrics();
    assert_eq!(
        m.generations_published,
        m.redeal_epochs + m.resplit_epochs + m.migrate_epochs + m.repack_epochs
            + m.replicate_epochs,
        "fleet repartition counters inconsistent"
    );
    ArmResult {
        makespan_gbps: fleet.makespan_sim_gbps(),
        aggregate_gbps: fleet.aggregate_sim_gbps(),
        replicas_created: m.replicas_created,
        replicas_live: fleet.replica_set().count(),
    }
}

/// Turn the load uniform and audit the subside path: the hot shard's
/// combined share collapses under the exit floor and every replica
/// retires.  Returns (epochs until empty, drop witnessed in the trace).
fn run_subside(fleet: &FleetService, budget: usize) -> (usize, bool) {
    let mut gen = RequestGen::new(WorkloadSpec {
        seed: 4242,
        ..spec(Distribution::Uniform)
    });
    let mut epochs = budget;
    for i in 0..budget {
        let rows = Arc::new(gen.next_request());
        let out = fleet.lookup(Arc::clone(&rows)).expect("lookup");
        fleet.recycle(out);
        fleet.control_epoch();
        if fleet.replica_set().is_empty() {
            epochs = i + 1;
            break;
        }
    }
    let dropped = fleet
        .control_decisions()
        .iter()
        .any(|d| d.acted == Some(Lever::Replicate) && d.why.contains("dropped"));
    (epochs, dropped)
}

fn main() {
    let args: Vec<String> = std::env::args().collect();
    let smoke = args.iter().any(|a| a == "--smoke");

    let table = Table::synthetic(ROWS, D);
    let (warm, measured) = if smoke { (40, 40) } else { (120, 200) };
    println!(
        "# Replication ({}, d={D}, {ROWS} rows, {CARDS} cards)",
        if smoke { "smoke" } else { "full" },
    );

    let arms: &[(&str, Distribution)] = &[
        ("zipf1.1", Distribution::Zipf { theta: 1.1 }),
        (
            "drift-zipf1.1",
            Distribution::Drift {
                inner: Box::new(Distribution::Zipf { theta: 1.1 }),
                period: (warm + measured) as u64 / 3,
            },
        ),
        ("uniform", Distribution::Uniform),
    ];

    println!(
        "{:>14} {:>11} {:>13} {:>13} {:>9} {:>8}",
        "workload", "ladder", "makespan_gbps", "device_gbps", "replicas", "ratio"
    );
    let mut rows_out = Vec::new();
    let mut subside = None;
    for (name, dist) in arms {
        let mut arm_of: Vec<ArmResult> = Vec::new();
        for replicate in [false, true] {
            let fleet = build_fleet(&table, replicate);
            let mut gen = RequestGen::new(spec(dist.clone()));
            let r = run_arm(&fleet, &table, &mut gen, warm, measured);
            if !replicate {
                assert_eq!(r.replicas_created, 0, "unarmed fleet must never replicate");
            }
            println!(
                "{:>14} {:>11} {:>13.2} {:>13.2} {:>9} {:>8}",
                name,
                if replicate { "replicated" } else { "migration" },
                r.makespan_gbps,
                r.aggregate_gbps,
                r.replicas_created,
                "-"
            );
            // The subside audit rides the replicated zipf arm: flat load
            // must retire every replica (decision-trace witnessed).
            if replicate && *name == "zipf1.1" {
                subside = Some(run_subside(&fleet, 80));
            }
            fleet.shutdown();
            arm_of.push(r);
        }
        let ratio = arm_of[1].makespan_gbps / arm_of[0].makespan_gbps.max(1e-12);
        println!(
            "{:>14} {:>11} {:>13} {:>13} {:>9} {:>8.2}",
            name, "ratio", "-", "-", "-", ratio
        );
        rows_out.push((*name, arm_of.remove(0), arm_of.remove(0), ratio));
    }
    let (subside_epochs, subside_witnessed) = subside.expect("zipf arm always runs");
    println!(
        "# subside: replicas empty after {subside_epochs} uniform epochs \
         (drop in decision trace: {subside_witnessed})"
    );

    // --- acceptance (full mode only; smoke just emits the numbers) --------
    if !smoke {
        for skew in ["zipf1.1", "drift-zipf1.1"] {
            let r = rows_out.iter().find(|r| r.0 == skew).unwrap();
            assert!(
                r.2.replicas_created >= 1,
                "{skew}: replicate lever never fired — the ratio would be vacuous"
            );
            assert!(
                r.3 >= 1.4,
                "{skew}: replicated {:.2} GB/s not >= 1.4x migration-only {:.2} GB/s",
                r.2.makespan_gbps,
                r.1.makespan_gbps
            );
        }
        let uni = rows_out.iter().find(|r| r.0 == "uniform").unwrap();
        assert_eq!(
            uni.2.replicas_created, 0,
            "uniform load must never clear the hot-share gate"
        );
        assert!(
            (uni.3 - 1.0).abs() <= 0.05,
            "uniform parity broken: replicated {:.2} vs migration-only {:.2} GB/s",
            uni.2.makespan_gbps,
            uni.1.makespan_gbps
        );
        assert!(
            subside_epochs < 80 && subside_witnessed,
            "subsided load left replicas standing (empty after {subside_epochs} epochs, \
             trace witnessed: {subside_witnessed})"
        );
    }

    let json = Json::obj(vec![
        ("workload", Json::str("replication")),
        ("smoke", Json::num(if smoke { 1u32 } else { 0u32 })),
        ("d", Json::num(D as u32)),
        ("rows", Json::num(ROWS as u32)),
        ("cards", Json::num(CARDS as u32)),
        (
            "arms",
            Json::arr(
                rows_out
                    .iter()
                    .map(|(name, mig, rep, ratio)| {
                        Json::obj(vec![
                            ("skew", Json::str(name)),
                            ("migration_makespan_gbps", Json::num(mig.makespan_gbps)),
                            ("replicated_makespan_gbps", Json::num(rep.makespan_gbps)),
                            ("migration_device_gbps", Json::num(mig.aggregate_gbps)),
                            ("replicated_device_gbps", Json::num(rep.aggregate_gbps)),
                            ("replicas_created", Json::num(rep.replicas_created as u32)),
                            ("replicas_live_end", Json::num(rep.replicas_live as u32)),
                            ("ratio", Json::num(*ratio)),
                        ])
                    })
                    .collect(),
            ),
        ),
        (
            "subside",
            Json::obj(vec![
                ("epochs_to_empty", Json::num(subside_epochs as u32)),
                (
                    "trace_witnessed",
                    Json::num(if subside_witnessed { 1u32 } else { 0u32 }),
                ),
            ]),
        ),
    ]);
    let path = "BENCH_replication.json";
    match std::fs::write(path, json.to_string_pretty()) {
        Ok(()) => println!("[json] wrote {path}"),
        Err(e) => eprintln!("warning: could not write {path}: {e}"),
    }
}

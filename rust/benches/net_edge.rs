//! Bench: what the network front door costs — the loopback TCP binary
//! protocol (single pinned connection, then a pooled multi-connection
//! closed loop) against the in-process facade baseline on the same sim
//! backend with *probed* timing (no DES at serve time, no pacing), so
//! the delta isolates framing + syscalls + the connection pool, exactly
//! the overhead EXPERIMENTS.md §Net budgets.
//!
//! Emits `BENCH_net.json` (in the crate directory under `cargo bench`)
//! so the wire-overhead trajectory is comparable across PRs.
//!
//! Flags (after `--`): `--smoke` shrinks the sweep for CI.

use std::sync::Arc;
use std::time::Instant;

use a100win::coordinator::{BatcherConfig, Table, WindowPlan};
use a100win::net::{ClientConfig, NetClient, NetConfig, NetServer, RemotePool, Target};
use a100win::prelude::PlacementPolicy;
use a100win::probe::TopologyMap;
use a100win::service::{Service, SimBackend, SimBackendConfig, SimTiming};
use a100win::util::json::Json;
use a100win::util::rng::Rng;

const D: usize = 32;
const ROWS: u64 = 32_768;
const POOL_CONNS: usize = 4;

fn map4() -> TopologyMap {
    TopologyMap {
        groups: (0..4).map(|g| vec![g]).collect(),
        reach_bytes: 1 << 33,
        solo_gbps: vec![100.0; 4],
        independent: true,
        card_id: "net-bench".into(),
    }
}

fn backend(table: &Table) -> Arc<SimBackend> {
    let mut cfg = SimBackendConfig::new(PlacementPolicy::GroupToChunk);
    cfg.batcher = BatcherConfig {
        max_batch_rows: 8_192,
        max_wait: std::time::Duration::from_micros(200),
        max_pending: 4_096,
    };
    let plan = WindowPlan::split(table.rows, (D * 4) as u64, 4);
    Arc::new(
        SimBackend::start(cfg, &map4(), plan, table.view(), SimTiming::Probed)
            .expect("start sim backend"),
    )
}

fn payloads(table: &Table, batch: usize, seed: u64) -> Vec<Vec<u64>> {
    let mut rng = Rng::seed_from_u64(seed);
    (0..128)
        .map(|_| (0..batch).map(|_| rng.gen_range(table.rows)).collect())
        .collect()
}

fn spot_check(table: &Table, i: usize, rows: &[u64], out: &[f32]) {
    assert_eq!(out.len(), rows.len() * D, "short response");
    if i % 64 == 0 {
        for (k, &row) in rows.iter().enumerate() {
            for j in 0..D {
                assert_eq!(out[k * D + j], table.expected(row, j), "row {row} col {j}");
            }
        }
    }
}

/// In-process baseline: the facade without any wire.
fn run_local(service: &Service, table: &Table, requests: usize, batch: usize) -> f64 {
    let pay = payloads(table, batch, 11);
    let t0 = Instant::now();
    for i in 0..requests {
        let rows = &pay[i % pay.len()];
        let out = service.lookup(Arc::new(rows.clone())).expect("local lookup");
        spot_check(table, i, rows, &out);
        service.recycle(out);
    }
    t0.elapsed().as_secs_f64()
}

/// One pinned connection, strict request→response: the per-round-trip
/// floor of the wire path (framing + 2 syscalls + decode, no pooling).
fn run_remote_pinned(client: &mut NetClient, table: &Table, requests: usize, batch: usize) -> f64 {
    let pay = payloads(table, batch, 11);
    let t0 = Instant::now();
    for i in 0..requests {
        let rows = &pay[i % pay.len()];
        let partial = client
            .lookup_reuse(rows, None)
            .expect("remote lookup");
        assert!(!partial, "clean loopback run went partial");
    }
    t0.elapsed().as_secs_f64()
}

/// Pooled closed loop: `POOL_CONNS` threads each running request→response
/// through the shared pool — the `bench-serve --remote` shape.
fn run_remote_pool(pool: &RemotePool, table: &Table, requests: usize, batch: usize) -> f64 {
    let per_thread = requests / POOL_CONNS;
    let t0 = Instant::now();
    std::thread::scope(|s| {
        for t in 0..POOL_CONNS {
            let pay = payloads(table, batch, 11 + t as u64);
            s.spawn(move || {
                for i in 0..per_thread {
                    pool.request_pinned(&pay[i % pay.len()], None)
                        .expect("pooled remote lookup");
                }
            });
        }
    });
    t0.elapsed().as_secs_f64()
}

fn main() {
    let smoke = std::env::args().any(|a| a == "--smoke");
    let batches: &[usize] = &[16, 256, 2048];
    let total_rows: usize = if smoke { 65_536 } else { 1 << 20 };

    let table = Table::synthetic(ROWS, D);
    let service = Service::new(backend(&table));
    let mut server = NetServer::start(
        Target::Single(Service::new(backend(&table))),
        NetConfig::default(),
    )
    .expect("start net server");
    let addr = server.addr().to_string();
    let mut pinned = NetClient::connect(&addr, ClientConfig::default()).expect("connect");
    let pool = RemotePool::new(addr, ClientConfig::default(), POOL_CONNS);
    pool.connect_warm(POOL_CONNS).expect("warm pool");

    println!("# Network edge ({}, d={D}, {ROWS} rows)", if smoke { "smoke" } else { "full" });
    println!(
        "{:>14} {:>6} {:>10} {:>14} {:>10}",
        "arm", "batch", "requests", "requests/s", "us/req"
    );

    let mut arms = Vec::new();
    for &batch in batches {
        let requests = (total_rows / batch).max(POOL_CONNS * 8);
        // Warmup fills every pool (slabs, shells, frame buffers) so the
        // measured loops see steady state.
        run_local(&service, &table, 64, batch);
        run_remote_pinned(&mut pinned, &table, 64, batch);
        run_remote_pool(&pool, &table, POOL_CONNS * 8, batch);
        let runs: [(&str, f64); 3] = [
            ("local", run_local(&service, &table, requests, batch)),
            (
                "remote-pinned",
                run_remote_pinned(&mut pinned, &table, requests, batch),
            ),
            (
                "remote-pooled",
                run_remote_pool(&pool, &table, requests, batch),
            ),
        ];
        for (arm, secs) in runs {
            let rps = requests as f64 / secs;
            let us = secs * 1e6 / requests as f64;
            println!("{arm:>14} {batch:>6} {requests:>10} {rps:>14.0} {us:>10.2}");
            arms.push((arm, batch, requests, rps, us));
        }
    }

    let json = Json::obj(vec![
        ("workload", Json::str("net_edge")),
        ("smoke", Json::num(if smoke { 1u32 } else { 0u32 })),
        ("d", Json::num(D as u32)),
        ("rows", Json::num(ROWS as u32)),
        ("pool_conns", Json::num(POOL_CONNS as u32)),
        (
            "arms",
            Json::arr(
                arms.iter()
                    .map(|&(arm, batch, requests, rps, us)| {
                        Json::obj(vec![
                            ("arm", Json::str(arm)),
                            ("batch", Json::num(batch as u32)),
                            ("requests", Json::num(requests as u32)),
                            ("requests_per_s", Json::num(rps)),
                            ("us_per_request", Json::num(us)),
                        ])
                    })
                    .collect(),
            ),
        ),
    ]);
    let path = "BENCH_net.json";
    match std::fs::write(path, json.to_string_pretty()) {
        Ok(()) => println!("[json] wrote {path}"),
        Err(e) => eprintln!("warning: could not write {path}: {e}"),
    }

    service.shutdown();
    let report = server.drain(std::time::Duration::from_secs(5));
    assert!(report.completed, "bench drain left work behind: {report:?}");
}

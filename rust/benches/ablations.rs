//! Ablations over the design choices DESIGN.md calls out: what creates the
//! cliff, what moves it, and when the paper's technique stops mattering.
//!
//!   1. page size      — reach = entries x page; the cliff tracks reach.
//!   2. walker count   — sets the post-cliff floor, not the plateau.
//!   3. associativity  — low assoc erodes the plateau edge.
//!   4. window count   — group-to-chunk works with any windows <= groups.
//!   5. access skew    — zipf re-use keeps the TLB effective past reach.
//!   6. txn size       — the paper's §2.1 aside.

use a100win::config::{MachineConfig, GIB};
use a100win::coordinator::PlacementPolicy;
use a100win::experiments::common::{ground_truth_map, run_policy};
use a100win::experiments::{txn, Effort};
use a100win::sim::{Machine, MeasurementSpec, MemRegion, Pattern};
use a100win::util::benchkit::Table;

const PER_SM: u64 = 3_000;

fn uniform_run(machine: &Machine, region_gib: u64, seed: u64) -> f64 {
    let sms = machine.topology().all_sms();
    let spec = MeasurementSpec::uniform_all(
        &sms,
        Pattern::Uniform(MemRegion::new(0, region_gib * GIB)),
        PER_SM,
        seed,
    );
    machine.run(&spec).gbps
}

fn ablate_page_size() {
    println!("\n## Ablation 1: page size (reach = 32768 entries x page)");
    let mut t = Table::new(&["page_mib", "reach_gib", "gbps_at_48gib", "gbps_at_80gib"]);
    for page_mib in [1u64, 2, 4] {
        let mut cfg = MachineConfig::a100_80gb();
        cfg.tlb.page_bytes = page_mib << 20;
        let reach = cfg.tlb.reach_bytes() / GIB;
        let m = Machine::new(cfg).unwrap();
        t.row(&[
            page_mib.to_string(),
            reach.to_string(),
            format!("{:.0}", uniform_run(&m, 48, 1)),
            format!("{:.0}", uniform_run(&m, 80, 2)),
        ]);
    }
    t.print();
    t.write_csv("ablation_page_size.csv");
}

fn ablate_walkers() {
    println!("\n## Ablation 2: page walkers per group (post-cliff floor)");
    let mut t = Table::new(&["walkers", "gbps_at_32gib", "gbps_at_80gib"]);
    for walkers in [4usize, 8, 16, 32] {
        let mut cfg = MachineConfig::a100_80gb();
        cfg.tlb.walkers_per_group = walkers;
        let m = Machine::new(cfg).unwrap();
        t.row(&[
            walkers.to_string(),
            format!("{:.0}", uniform_run(&m, 32, 3)),
            format!("{:.0}", uniform_run(&m, 80, 4)),
        ]);
    }
    t.print();
    t.write_csv("ablation_walkers.csv");
}

fn ablate_associativity() {
    println!("\n## Ablation 3: TLB associativity (plateau edge at reach)");
    let mut t = Table::new(&["assoc", "gbps_at_60gib", "gbps_at_64gib"]);
    for assoc in [2usize, 8, 32] {
        let mut cfg = MachineConfig::a100_80gb();
        cfg.tlb.associativity = assoc;
        let m = Machine::new(cfg).unwrap();
        t.row(&[
            assoc.to_string(),
            format!("{:.0}", uniform_run(&m, 60, 5)),
            format!("{:.0}", uniform_run(&m, 64, 6)),
        ]);
    }
    t.print();
    t.write_csv("ablation_assoc.csv");
}

fn ablate_window_count() {
    println!("\n## Ablation 4: group-to-chunk window count at 80 GiB");
    let machine = Machine::new(MachineConfig::a100_80gb()).unwrap();
    let map = ground_truth_map(&machine);
    let mut t = Table::new(&["windows", "gbps"]);
    for windows in [2usize, 4, 7, 14] {
        let gbps = run_policy(
            &machine,
            &map,
            PlacementPolicy::GroupToChunk,
            80,
            windows,
            PER_SM,
            7,
        );
        t.row(&[windows.to_string(), format!("{gbps:.0}")]);
    }
    t.print();
    t.write_csv("ablation_windows.csv");
}

fn ablate_skew() {
    println!("\n## Ablation 5: access skew at 80 GiB, naive placement");
    let machine = Machine::new(MachineConfig::a100_80gb()).unwrap();
    let sms = machine.topology().all_sms();
    let mut t = Table::new(&["workload", "gbps", "tlb_hit_rate"]);
    let region = MemRegion::new(0, 80 * GIB);
    let cases: Vec<(&str, Pattern)> = vec![
        ("uniform", Pattern::Uniform(region)),
        (
            "zipf_0.99",
            Pattern::Zipf {
                region,
                theta: 0.99,
            },
        ),
        ("sequential", Pattern::Sequential(region)),
    ];
    for (name, pattern) in cases {
        let spec = MeasurementSpec::uniform_all(&sms, pattern, PER_SM, 8);
        let meas = machine.run(&spec);
        t.row(&[
            name.to_string(),
            format!("{:.0}", meas.gbps),
            format!("{:.3}", meas.tlb_hit_rate),
        ]);
    }
    t.print();
    t.write_csv("ablation_skew.csv");
}

fn main() {
    println!("# Ablation benches (A100-80GB preset, {PER_SM} accesses/SM)");
    ablate_page_size();
    ablate_walkers();
    ablate_associativity();
    ablate_window_count();
    ablate_skew();
    ablate_nvlink();

    println!("\n## §2.1 aside: transaction-size sweep");
    let rows = txn::run(Effort::Quick, 9);
    let t = txn::table(&rows);
    t.print();
    t.write_csv("ablation_txn.csv");
    txn::check(&rows).expect("txn sweep shape");
}

fn ablate_nvlink() {
    println!("\n## Ablation 6: NVLink remote access (the paper's §1.2 TLB)");
    use a100win::sim::nvlink::{run_remote, NvlinkConfig, PeerSpec};
    let cfg = MachineConfig::a100_80gb();
    let nv = NvlinkConfig::a100();
    let mut t = Table::new(&["region_gib", "peers", "gbps", "tlb_hit_rate"]);
    for (gib, peers) in [(32u64, 4usize), (60, 4), (80, 4), (80, 1)] {
        let specs: Vec<PeerSpec> = (0..peers)
            .map(|_| PeerSpec {
                pattern: Pattern::Uniform(MemRegion::new(0, gib * GIB)),
            })
            .collect();
        let m = run_remote(&cfg, &nv, &specs, 10_000, 11);
        t.row(&[
            gib.to_string(),
            peers.to_string(),
            format!("{:.0}", m.gbps),
            format!("{:.3}", m.tlb_hit_rate),
        ]);
    }
    // Sender-side windowing control: does NOT restore speed (single TLB).
    let windows: Vec<PeerSpec> = (0..4)
        .map(|i| PeerSpec {
            pattern: Pattern::Uniform(MemRegion::new(i * 20 * GIB, 20 * GIB)),
        })
        .collect();
    let m = run_remote(&cfg, &nv, &windows, 10_000, 12);
    t.row(&[
        "80(win)".into(),
        "4".into(),
        format!("{:.0}", m.gbps),
        format!("{:.3}", m.tlb_hit_rate),
    ]);
    t.print();
    t.write_csv("ablation_nvlink.csv");
    println!("(windowed senders do not help: the ingress TLB is a single shared structure,");
    println!(" unlike the per-group SM TLBs the paper's technique exploits)");
}

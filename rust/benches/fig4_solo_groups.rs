//! Bench: regenerate paper Figure 4 (each resource group individually).

use a100win::experiments::{fig4, Effort};
use a100win::util::benchkit;

fn main() {
    let effort = Effort::from_env();
    let rows = fig4::run(effort, 42);
    println!("# Figure 4: running each resource group individually");
    let t = fig4::table(&rows);
    t.print();
    t.write_csv("fig4.csv");
    fig4::check(&rows).expect("figure 4 shape");

    benchkit::bench("solo_group_measurement", 1, 5, || {
        benchkit::black_box(fig4::run(Effort::Quick, 43));
    });
}

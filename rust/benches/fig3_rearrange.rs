//! Bench: regenerate paper Figure 3 (index rearrangement -> group blocks)
//! and time the clustering step in isolation.

use a100win::experiments::{fig3, Effort};
use a100win::probe::cluster;
use a100win::util::benchkit;

fn main() {
    let effort = Effort::from_env();
    let f = fig3::run(effort, 42);
    println!("# Figure 3: rearranged SM indices");
    print!("{}", fig3::render(&f));
    println!("{}", fig3::summary(&f));
    assert_eq!(f.clustering.groups.len(), 14, "must discover 14 groups");

    std::fs::create_dir_all("bench_out").ok();
    std::fs::write(
        "bench_out/fig3.csv",
        f.fig2.matrix.to_csv(&f.clustering.permutation),
    )
    .expect("write fig3.csv");
    println!("[csv] wrote bench_out/fig3.csv");

    benchkit::bench("cluster_108x108_matrix", 1, 20, || {
        benchkit::black_box(cluster(&f.fig2.matrix));
    });
}

//! Bench: serving hot-path throughput — the lock-light, allocation-free
//! slab/ring data path against the retained `--legacy-path` oracle
//! (mutexed accumulator + mpsc channels + per-ticket `sync_channel` +
//! per-job gather `Vec`).
//!
//! Closed-loop pipelined requests against the sim backend with *probed*
//! timing (no DES at serve time, no pacing), so the numbers isolate the
//! host serving software — exactly the overhead EXPERIMENTS.md §Perf L4
//! targets.  Sweeps request batch sizes 1 / 64 / 1024 rows and 1–8 cards
//! (cards > 1 run the fleet facade over zero-copy shards of one table).
//!
//! Emits `BENCH_serve.json` (in the crate directory under `cargo bench`)
//! so the §Serve trajectory is comparable across PRs.
//!
//! Flags (after `--`): `--smoke` shrinks the sweep for CI;
//! `--legacy-path` runs only the oracle arm (both arms run by default).

use std::sync::Arc;
use std::time::Instant;

use a100win::coordinator::{BatcherConfig, CardSpec, Table, WindowPlan};
use a100win::prelude::PlacementPolicy;
use a100win::probe::TopologyMap;
use a100win::service::{FleetService, Service, SimBackend, SimBackendConfig, SimTiming};
use a100win::util::json::Json;
use a100win::util::rng::Rng;

const D: usize = 32;
const ROWS_PER_CARD: u64 = 32_768;
/// Pipelined in-flight tickets (closed loop, windowed).
const DEPTH: usize = 64;

/// A synthetic probed map: `groups` single-SM resource groups, reach far
/// above the per-card table so placement never constrains the sweep (the
/// bench measures the serving software, not the window construction).
fn map(groups: usize, card: usize) -> TopologyMap {
    TopologyMap {
        groups: (0..groups).map(|g| vec![g]).collect(),
        reach_bytes: 1 << 33,
        solo_gbps: vec![100.0; groups],
        independent: true,
        card_id: format!("bench-card-{card}"),
    }
}

fn quick_batcher() -> BatcherConfig {
    BatcherConfig {
        max_batch_rows: 8_192,
        max_wait: std::time::Duration::from_micros(200),
        max_pending: 4_096,
    }
}

enum Target {
    Single(Service),
    Fleet(FleetService),
}

impl Target {
    fn build(cards: usize, legacy: bool, table: &Table) -> Target {
        let mut cfg = SimBackendConfig::new(PlacementPolicy::GroupToChunk);
        cfg.batcher = quick_batcher();
        cfg.legacy_path = legacy;
        if cards == 1 {
            let plan = WindowPlan::split(table.rows, (D * 4) as u64, 4);
            let backend = Arc::new(
                SimBackend::start(cfg, &map(4, 0), plan, table.view(), SimTiming::Probed)
                    .expect("start sim backend"),
            );
            Target::Single(Service::new(backend))
        } else {
            let specs = (0..cards)
                .map(|c| {
                    (
                        CardSpec {
                            map: map(4, c),
                            memory_bytes: ROWS_PER_CARD * (D * 4) as u64 * 2,
                        },
                        SimTiming::Probed,
                    )
                })
                .collect();
            let fleet = FleetService::build_sim_with(
                specs,
                table,
                a100win::service::FleetConfig {
                    batcher: quick_batcher(),
                    legacy_path: legacy,
                    ..Default::default()
                },
            )
            .expect("build fleet");
            Target::Fleet(fleet)
        }
    }

    /// Run `requests` pipelined lookups of `batch` rows; returns wall
    /// seconds.  Every response is length-checked and one in 64 is
    /// verified row-by-row against the synthetic table (merged-row
    /// correctness rides inside the measurement, cheaply).
    fn drive(&self, table: &Table, requests: usize, batch: usize, seed: u64) -> f64 {
        let mut rng = Rng::seed_from_u64(seed);
        let payloads: Vec<Arc<Vec<u64>>> = (0..128)
            .map(|_| Arc::new((0..batch).map(|_| rng.gen_range(table.rows)).collect()))
            .collect();
        let t0 = Instant::now();
        match self {
            Target::Single(service) => {
                let mut inflight = std::collections::VecDeque::new();
                for i in 0..requests {
                    let rows = Arc::clone(&payloads[i % payloads.len()]);
                    inflight.push_back((i, Arc::clone(&rows), service.submit(rows, None).unwrap()));
                    if inflight.len() >= DEPTH {
                        let (i, rows, t) = inflight.pop_front().unwrap();
                        finish(service, table, i, &rows, t.wait().unwrap());
                    }
                }
                while let Some((i, rows, t)) = inflight.pop_front() {
                    finish(service, table, i, &rows, t.wait().unwrap());
                }
            }
            Target::Fleet(fleet) => {
                let mut inflight = std::collections::VecDeque::new();
                for i in 0..requests {
                    let rows = Arc::clone(&payloads[i % payloads.len()]);
                    inflight.push_back((i, Arc::clone(&rows), fleet.submit(rows, None).unwrap()));
                    if inflight.len() >= DEPTH {
                        let (i, rows, t) = inflight.pop_front().unwrap();
                        let out = t.wait().unwrap();
                        verify(table, i, &rows, &out);
                        fleet.recycle(out);
                    }
                }
                while let Some((i, rows, t)) = inflight.pop_front() {
                    let out = t.wait().unwrap();
                    verify(table, i, &rows, &out);
                    fleet.recycle(out);
                }
            }
        }
        t0.elapsed().as_secs_f64()
    }

    fn shutdown(&self) {
        match self {
            Target::Single(s) => s.shutdown(),
            Target::Fleet(f) => f.shutdown(),
        }
    }
}

fn verify(table: &Table, i: usize, rows: &[u64], out: &[f32]) {
    assert_eq!(out.len(), rows.len() * D, "short response");
    if i % 64 == 0 {
        for (k, &row) in rows.iter().enumerate() {
            for j in 0..D {
                assert_eq!(out[k * D + j], table.expected(row, j), "row {row} col {j}");
            }
        }
    }
}

fn finish(service: &Service, table: &Table, i: usize, rows: &[u64], out: Vec<f32>) {
    verify(table, i, rows, &out);
    // Close the allocation loop: slabs go back to the backend pool.
    service.recycle(out);
}

fn main() {
    let args: Vec<String> = std::env::args().collect();
    let smoke = args.iter().any(|a| a == "--smoke");
    let legacy_only = args.iter().any(|a| a == "--legacy-path");

    let card_counts: &[usize] = if smoke { &[1, 2] } else { &[1, 2, 4, 8] };
    let batches: &[usize] = &[1, 64, 1024];
    let paths: &[bool] = if legacy_only {
        &[true]
    } else {
        &[false, true] // new first, then the oracle
    };

    println!(
        "# Serve hot path ({}, d={D}, {} rows/card, depth {DEPTH})",
        if smoke { "smoke" } else { "full" },
        ROWS_PER_CARD
    );
    println!(
        "{:>8} {:>6} {:>6} {:>10} {:>14} {:>10}",
        "path", "cards", "batch", "requests", "requests/s", "ns/row"
    );

    let mut arms = Vec::new();
    for &cards in card_counts {
        let table = Table::synthetic(ROWS_PER_CARD * cards as u64, D);
        for &legacy in paths {
            let target = Target::build(cards, legacy, &table);
            for &batch in batches {
                // Equal *row* volume per point so every arm does
                // comparable work; floor keeps tiny batches honest.
                let total_rows: usize = if smoke { 65_536 } else { 1 << 20 };
                let requests = (total_rows / batch).clamp(64, 16_384);
                // Warmup: fill slab/shell pools and the calibration memo.
                target.drive(&table, requests / 4, batch, 1);
                let wall = target.drive(&table, requests, batch, 2);
                let rps = requests as f64 / wall;
                let ns_per_row = wall * 1e9 / (requests * batch) as f64;
                let path = if legacy { "legacy" } else { "new" };
                println!(
                    "{path:>8} {cards:>6} {batch:>6} {requests:>10} {rps:>14.0} {ns_per_row:>10.1}"
                );
                arms.push((path, cards, batch, requests, rps, ns_per_row));
            }
            target.shutdown();
        }
    }

    // Pair up new-vs-legacy speedups per (cards, batch).
    let mut speedups = Vec::new();
    for &(_, cards, batch, _, rps_new, _) in arms.iter().filter(|a| a.0 == "new") {
        if let Some(&(_, _, _, _, rps_old, _)) = arms
            .iter()
            .find(|a| a.0 == "legacy" && a.1 == cards && a.2 == batch)
        {
            speedups.push((cards, batch, rps_new / rps_old));
        }
    }
    for &(cards, batch, s) in &speedups {
        println!("# speedup new/legacy @ cards={cards} batch={batch}: {s:.2}x");
    }

    let json = Json::obj(vec![
        ("workload", Json::str("serve_hotpath")),
        ("smoke", Json::num(if smoke { 1u32 } else { 0u32 })),
        ("d", Json::num(D as u32)),
        ("rows_per_card", Json::num(ROWS_PER_CARD as u32)),
        ("depth", Json::num(DEPTH as u32)),
        (
            "arms",
            Json::arr(
                arms.iter()
                    .map(|&(path, cards, batch, requests, rps, nsr)| {
                        Json::obj(vec![
                            ("path", Json::str(path)),
                            ("cards", Json::num(cards as u32)),
                            ("batch", Json::num(batch as u32)),
                            ("requests", Json::num(requests as u32)),
                            ("requests_per_s", Json::num(rps)),
                            ("ns_per_row", Json::num(nsr)),
                        ])
                    })
                    .collect(),
            ),
        ),
        (
            "speedup_new_vs_legacy",
            Json::arr(
                speedups
                    .iter()
                    .map(|&(cards, batch, s)| {
                        Json::obj(vec![
                            ("cards", Json::num(cards as u32)),
                            ("batch", Json::num(batch as u32)),
                            ("speedup", Json::num(s)),
                        ])
                    })
                    .collect(),
            ),
        ),
    ]);
    let path = "BENCH_serve.json";
    match std::fs::write(path, json.to_string_pretty()) {
        Ok(()) => println!("[json] wrote {path}"),
        Err(e) => eprintln!("warning: could not write {path}: {e}"),
    }
}

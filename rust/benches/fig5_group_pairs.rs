//! Bench: regenerate paper Figure 5 (pairs of groups, disjoint regions).

use a100win::experiments::{fig5, Effort};
use a100win::util::benchkit;

fn main() {
    let effort = Effort::from_env();
    let f = fig5::run(effort, 42);
    println!("# Figure 5: running pairs of resource groups");
    let t = fig5::table(&f);
    t.print();
    t.write_csv("fig5.csv");
    fig5::check(&f).expect("figure 5 shape");
    let worst = f
        .pairs
        .iter()
        .map(|p| (p.gbps / p.solo_sum - 1.0).abs())
        .fold(0.0f64, f64::max);
    println!("worst deviation from independence: {:.1}%", worst * 100.0);

    benchkit::bench("group_pair_measurement", 1, 5, || {
        benchkit::black_box(fig5::run(Effort::Quick, 43));
    });
}

//! Bench: regenerate paper Figure 1 (throughput vs region size, uniform and
//! SM-to-chunk arms) and time the sweep.  CSV lands in bench_out/fig1.csv.

use a100win::experiments::{fig1, Effort};
use a100win::util::benchkit;

fn main() {
    let effort = Effort::from_env();
    let rows = fig1::run(effort, 42);
    println!("# Figure 1: memory throughput for random access (GB/s)");
    let t = fig1::table(&rows);
    t.print();
    t.write_csv("fig1.csv");
    fig1::check(&rows).expect("figure 1 shape");

    benchkit::bench("fig1_sweep", 0, 3, || {
        benchkit::black_box(fig1::run(Effort::Quick, 43));
    });
}

//! Bench: the L3 coordinator's request hot path.
//!
//! Three levels, innermost out:
//!   1. router split+merge alone (pure CPU),
//!   2. batcher submit->drain round trip,
//!   3. full server lookups over PJRT artifacts (requires `make artifacts`).

use std::time::{Duration, Instant};

use a100win::coordinator::{
    merge_rows, BatcherConfig, EmbeddingServer, Placement, PlacementPolicy, Router,
    ServerConfig, Table, WindowPlan,
};
use a100win::probe::TopologyMap;
use a100win::runtime::Runtime;
use a100win::util::benchkit::{self, black_box};
use a100win::util::rng::Rng;
use a100win::workload::{RequestGen, WorkloadSpec};

fn map14() -> TopologyMap {
    TopologyMap {
        groups: (0..14).map(|g| (g * 8..g * 8 + 8).collect()).collect(),
        reach_bytes: 64 << 30,
        solo_gbps: vec![120.0; 14],
        independent: true,
        card_id: "bench".into(),
    }
}

fn bench_router() {
    let map = map14();
    let total_rows: u64 = 1 << 24; // 16M rows = 2 GiB of 128 B lines
    let plan = WindowPlan::split(total_rows, 128, 14);
    let placement = Placement::build(PlacementPolicy::GroupToChunk, &map, &plan, 0).unwrap();
    let mut router = Router::new();
    let mut rng = Rng::seed_from_u64(1);
    let batch: Vec<u64> = (0..4096).map(|_| rng.gen_range(total_rows)).collect();

    // Throughput metric: routed rows/s.
    let iters = 2_000;
    let t = Instant::now();
    for _ in 0..iters {
        black_box(router.split(black_box(&batch), &plan, &placement));
    }
    let dt = t.elapsed();
    let rows_per_s = (iters as f64 * batch.len() as f64) / dt.as_secs_f64();
    println!("router split: {:.2} M rows/s (batch 4096, 14 windows)", rows_per_s / 1e6);

    benchkit::bench("router_split_4096", 10, 50, || {
        black_box(router.split(black_box(&batch), &plan, &placement));
    });

    // Zero-alloc steady state: shells recycled between splits.
    benchkit::bench("router_split_4096_recycled", 10, 50, || {
        let split = router.split(black_box(&batch), &plan, &placement);
        black_box(&split);
        router.recycle(split);
    });

    // Split + identity merge round trip.
    let d = 32;
    benchkit::bench("router_split_merge_4096x32", 5, 20, || {
        let split = router.split(&batch, &plan, &placement);
        let parts: Vec<Vec<f32>> = split
            .sub_batches
            .iter()
            .map(|sb| vec![1.0f32; sb.local_rows.len() * d])
            .collect();
        black_box(merge_rows(&split, &parts, d));
        router.recycle(split);
    });
}

fn bench_batcher() {
    let b: a100win::coordinator::Batcher<u32> = a100win::coordinator::Batcher::new(BatcherConfig {
        max_batch_rows: 4096,
        max_wait: Duration::from_millis(10),
        max_pending: 1 << 20,
    });
    let payload: std::sync::Arc<Vec<u64>> = std::sync::Arc::new(vec![7; 64]);
    benchkit::bench("batcher_submit_drain_64x64", 5, 50, || {
        for i in 0..64u32 {
            b.try_submit(std::sync::Arc::clone(&payload), None, i).unwrap();
        }
        black_box(b.next_batch().unwrap());
    });
}

fn bench_server() {
    let Ok(dir) = Runtime::default_artifacts_dir() else {
        println!("skipping server bench: no artifacts (run `make artifacts`)");
        return;
    };
    let rt = Runtime::new(&dir).unwrap();
    let meta = rt.manifest().first_of("lookup").unwrap();
    drop(rt);
    let windows = 2;
    let rows = (meta.n * windows) as u64;
    let table = Table::synthetic(rows, meta.d);
    let plan = WindowPlan::split(rows, 128, windows);
    let mut cfg = ServerConfig::new(dir);
    cfg.policy = PlacementPolicy::GroupToChunk;
    let map = TopologyMap {
        groups: (0..4).map(|g| vec![g]).collect(),
        reach_bytes: 64 << 30,
        solo_gbps: vec![120.0; 4],
        independent: true,
        card_id: "bench".into(),
    };
    let server = EmbeddingServer::start(cfg, &map, plan, table.view()).unwrap();

    let mut gen = RequestGen::new(WorkloadSpec::uniform(rows, 1024, 3));
    // Warm the executable caches.
    for _ in 0..3 {
        server.lookup(std::sync::Arc::new(gen.next_request())).unwrap();
    }
    let iters = 100;
    let t = Instant::now();
    for _ in 0..iters {
        black_box(server.lookup(std::sync::Arc::new(gen.next_request())).unwrap());
    }
    let dt = t.elapsed();
    let m = server.metrics();
    println!(
        "server end-to-end: {:.0} lookups/s of 1024 rows ({:.2} M rows/s); {}",
        iters as f64 / dt.as_secs_f64(),
        iters as f64 * 1024.0 / dt.as_secs_f64() / 1e6,
        m.report()
    );
    server.shutdown();
}

fn main() {
    println!("# Coordinator hot-path benchmarks");
    bench_router();
    bench_batcher();
    bench_server();
    bench_latency_curve();
}

/// Latency-throughput curve: open-loop Poisson offered-load sweep against
/// the live server (the classic serving-paper figure).
fn bench_latency_curve() {
    let Ok(dir) = Runtime::default_artifacts_dir() else {
        println!("skipping latency curve: no artifacts");
        return;
    };
    let rt = Runtime::new(&dir).unwrap();
    let meta = rt.manifest().first_of("lookup").unwrap();
    drop(rt);
    let rows = (meta.n * 2) as u64;
    let table = Table::synthetic(rows, meta.d);
    let plan = WindowPlan::split(rows, 128, 2);
    let mut cfg = ServerConfig::new(dir);
    cfg.policy = PlacementPolicy::GroupToChunk;
    cfg.batcher.max_wait = Duration::from_micros(500);
    let map = TopologyMap {
        groups: (0..4).map(|g| vec![g]).collect(),
        reach_bytes: 64 << 30,
        solo_gbps: vec![120.0; 4],
        independent: true,
        card_id: "curve".into(),
    };
    let service = a100win::service::Service::new(std::sync::Arc::new(
        EmbeddingServer::start(cfg, &map, plan, table.view()).unwrap(),
    ));
    // Warm the executable caches.
    let mut warm = RequestGen::new(WorkloadSpec::uniform(rows, 256, 1));
    for _ in 0..3 {
        service.lookup(std::sync::Arc::new(warm.next_request())).unwrap();
    }

    use a100win::workload::{drive, OpenLoopConfig};
    let mut t = a100win::util::benchkit::Table::new(&[
        "offered_rps",
        "achieved_rps",
        "mean_us",
        "p99_us",
        "dropped",
    ]);
    println!("\n# Open-loop latency-throughput curve (256-row lookups)");
    for offered in [100.0f64, 400.0, 800.0, 1600.0, 3200.0] {
        let mut gen = RequestGen::new(WorkloadSpec::uniform(rows, 256, 42));
        let point = drive(&service, &mut gen, offered, &OpenLoopConfig::default());
        t.row(&[
            format!("{offered:.0}"),
            format!("{:.0}", point.achieved_rps),
            format!("{:.0}", point.mean_latency_us),
            point.p99_latency_us.to_string(),
            point.dropped.to_string(),
        ]);
    }
    t.print();
    t.write_csv("latency_curve.csv");
}

//! Bench: raw discrete-event engine throughput, tracked across PRs.
//!
//! Measures simulated accesses per wall-clock second on the Fig-1 region
//! sweep workload (the engine's dominant consumer) in three ways:
//!
//!   1. single-thread, calendar-queue engine (`Machine::run`),
//!   2. single-thread, reference heap engine (the seed's event loop,
//!      `Machine::run_reference_heap`) — the speedup denominator,
//!   3. `Machine::run_many` scaling at 1/2/4/8 workers.
//!
//! Emits `BENCH_engine.json` (in the crate directory under `cargo bench`)
//! so the perf trajectory is comparable across PRs; see EXPERIMENTS.md
//! §Perf for the recorded history.

use std::time::Instant;

use a100win::config::{MachineConfig, GIB};
use a100win::sim::{Machine, MeasurementSpec, MemRegion, Pattern};
use a100win::util::json::Json;

/// The Fig-1 style workload: all 108 SMs, uniform random lines, region
/// sweep bracketing the 64 GiB cliff (both TLB-resident and thrash
/// regimes, which stress the event core differently).
fn sweep_specs(machine: &Machine, per_sm: u64, seed: u64) -> Vec<MeasurementSpec> {
    let sms = machine.topology().all_sms();
    [8u64, 24, 40, 56, 64, 72, 80]
        .iter()
        .map(|&gib| {
            MeasurementSpec::uniform_all(
                &sms,
                Pattern::Uniform(MemRegion::new(0, gib * GIB)),
                per_sm,
                seed ^ gib,
            )
        })
        .collect()
}

fn total_accesses(specs: &[MeasurementSpec]) -> u64 {
    specs
        .iter()
        .map(|s| s.accesses_per_sm * s.assignments.len() as u64)
        .sum()
}

/// Time `runs` serial passes of `f` over all specs; returns accesses/s.
fn accesses_per_s(
    specs: &[MeasurementSpec],
    runs: usize,
    mut f: impl FnMut(&MeasurementSpec),
) -> f64 {
    let t = Instant::now();
    for _ in 0..runs {
        for spec in specs {
            f(spec);
        }
    }
    (total_accesses(specs) * runs as u64) as f64 / t.elapsed().as_secs_f64()
}

fn main() {
    let machine = Machine::new(MachineConfig::a100_80gb()).unwrap();
    let per_sm: u64 = std::env::var("A100WIN_BENCH_PER_SM")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(3_000);
    let specs = sweep_specs(&machine, per_sm, 42);
    println!(
        "# Engine throughput (fig1 region sweep: {} points x 108 SMs x {per_sm} accesses)",
        specs.len()
    );

    // Warm the TLB-image cache and the allocator so both engines measure
    // steady state.
    for spec in &specs {
        machine.run(spec);
    }

    // 1. Calendar-queue engine, single thread.
    let cal = accesses_per_s(&specs, 3, |s| {
        std::hint::black_box(machine.run(s));
    });
    println!("calendar engine:        {:>10.2} M simulated accesses/s", cal / 1e6);

    // 2. Reference heap engine (the seed's event loop), single thread.
    let heap = accesses_per_s(&specs, 3, |s| {
        std::hint::black_box(machine.run_reference_heap(s));
    });
    println!("reference heap engine:  {:>10.2} M simulated accesses/s", heap / 1e6);
    let speedup = cal / heap;
    println!("single-thread speedup:  {speedup:>10.2}x");

    // 3. run_many scaling.  More sweep points than the serial case so each
    // worker stays busy.
    let many_specs: Vec<MeasurementSpec> = (0..4)
        .flat_map(|k| sweep_specs(&machine, per_sm, 1000 + k))
        .collect();
    let many_total = total_accesses(&many_specs) as f64;
    let mut scaling = Vec::new();
    let mut base_rate = 0.0;
    for workers in [1usize, 2, 4, 8] {
        let t = Instant::now();
        std::hint::black_box(machine.run_many_with(&many_specs, workers));
        let rate = many_total / t.elapsed().as_secs_f64();
        if workers == 1 {
            base_rate = rate;
        }
        let ratio = rate / base_rate;
        println!(
            "run_many x{workers}:            {:>10.2} M accesses/s  ({ratio:.2}x vs 1 worker)",
            rate / 1e6
        );
        scaling.push((workers, rate, ratio));
    }

    let json = Json::obj(vec![
        ("workload", Json::str("fig1_region_sweep")),
        ("sweep_points", Json::num(specs.len() as u32)),
        ("accesses_per_sm", Json::num(per_sm as u32)),
        (
            "single_thread",
            Json::obj(vec![
                ("calendar_accesses_per_s", Json::num(cal)),
                ("reference_heap_accesses_per_s", Json::num(heap)),
                ("speedup_vs_reference_heap", Json::num(speedup)),
            ]),
        ),
        (
            "run_many",
            Json::arr(
                scaling
                    .iter()
                    .map(|&(w, rate, ratio)| {
                        Json::obj(vec![
                            ("workers", Json::num(w as u32)),
                            ("accesses_per_s", Json::num(rate)),
                            ("scaling_vs_1_worker", Json::num(ratio)),
                        ])
                    })
                    .collect(),
            ),
        ),
    ]);
    let path = "BENCH_engine.json";
    match std::fs::write(path, json.to_string_pretty()) {
        Ok(()) => println!("[json] wrote {path}"),
        Err(e) => eprintln!("warning: could not write {path}: {e}"),
    }
}

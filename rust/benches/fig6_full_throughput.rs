//! Bench: regenerate paper Figure 6 — the headline result.  All three
//! placement policies vs region size; group-to-chunk must stay flat at the
//! HBM ceiling over the entire 80 GiB.

use a100win::experiments::{fig6, Effort};
use a100win::util::benchkit;

fn main() {
    let effort = Effort::from_env();
    let rows = fig6::run(effort, 42);
    println!("# Figure 6: memory throughput for random access, take 2 (GB/s)");
    let t = fig6::table(&rows);
    t.print();
    t.write_csv("fig6.csv");
    fig6::check(&rows).expect("figure 6 shape");

    let at80 = rows.iter().find(|r| r.region_gib == 80).unwrap();
    println!(
        "at 80 GiB: group-to-chunk {:.0} GB/s vs uniform {:.0} GB/s ({:.1}x)",
        at80.group_to_chunk_gbps,
        at80.uniform_gbps,
        at80.group_to_chunk_gbps / at80.uniform_gbps
    );

    benchkit::bench("fig6_sweep", 0, 3, || {
        benchkit::black_box(fig6::run(Effort::Quick, 43));
    });
}

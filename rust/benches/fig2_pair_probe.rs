//! Bench: regenerate paper Figure 2 (the SM-pair probe matrix) and time the
//! full 108x108 sweep.  CSV lands in bench_out/fig2.csv.

use a100win::experiments::{fig2, Effort};
use a100win::util::benchkit;

fn main() {
    let effort = Effort::from_env();
    let t = std::time::Instant::now();
    let f = fig2::run(effort, 42);
    let dt = t.elapsed();
    println!("# Figure 2: SM-pair probe matrix (smid order), probed in {:.1}s", dt.as_secs_f64());
    print!("{}", fig2::render(&f));
    std::fs::create_dir_all("bench_out").ok();
    std::fs::write("bench_out/fig2.csv", fig2::to_csv(&f)).expect("write fig2.csv");
    println!("[csv] wrote bench_out/fig2.csv");

    // Contrast metric: same-group vs cross-group pair throughput must be
    // bimodal; report the achieved gap (the probe's signal-to-noise).
    let mean = f.matrix.mean_offdiag();
    println!("mean off-diagonal pair throughput: {mean:.2} GB/s");

    benchkit::bench("single_pair_probe_run", 1, 10, || {
        use a100win::prelude::*;
        let m = a100win::experiments::common::paper_machine();
        let spec = MeasurementSpec::uniform_all(
            &[0, 1],
            Pattern::Uniform(MemRegion::whole(m.config().memory.total_bytes)),
            1_500,
            7,
        );
        benchkit::black_box(m.run(&spec));
    });
}

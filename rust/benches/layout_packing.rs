//! Bench: TLB-aware hot-row packing (the repack lever, coordinator::remap)
//! against the identity layout.
//!
//! The machine is deliberately stressed: each serving window (8 MiB) is
//! *larger* than a group's TLB reach (4 MiB), so the identity layout lives
//! on the paper's Fig-1 cliff (page-walk queueing), while the packed hot
//! prefix (≤ 2 MiB, page-granule aligned) fits comfortably under reach.
//! Two arms:
//!
//! * **serve** — the full serving stack (SimBackend, DES-calibrated
//!   timing) under zipf(1.1), drifting zipf, and uniform traffic, with the
//!   repack lever on vs off; scored on simulated aggregate GB/s (per-phase
//!   makespan, like tests/repartition.rs).
//! * **layout** — the DES directly: one group reading uniformly from the
//!   hot-prefix region vs the whole window; reports TLB/uTLB hit rates and
//!   GB/s, the microarchitectural account of *why* packing wins.
//!
//! Emits `BENCH_layout.json` (crate dir under `cargo bench`).  Flags
//! (after `--`): `--smoke` shrinks the sweep for CI and skips the ratio
//! assertion (the full run asserts packed ≥ 1.2x identity under zipf and
//! parity within 5% under uniform).

use std::sync::Arc;

use a100win::config::MachineConfig;
use a100win::coordinator::{
    AdaptiveConfig, BatcherConfig, ControlPlaneConfig, Lever, PlacementPolicy, RemapConfig, Table,
    WindowPlan,
};
use a100win::probe::TopologyMap;
use a100win::service::{Backend, Service, SimBackend, SimBackendConfig, SimTiming};
use a100win::sim::{Machine, MeasurementSpec, MemRegion, Pattern};
use a100win::util::json::Json;
use a100win::workload::{synth::Distribution, RequestGen, WorkloadSpec};

const D: usize = 32;
const ROW_BYTES: u64 = (D * 4) as u64; // 128 B, the paper's cache line
const WINDOWS: usize = 2;
const ROWS_PER_REQUEST: usize = 512;

/// Per-group TLB reach 4 MiB (64 x 64 KiB pages) over a 16 MiB table cut
/// into two 8 MiB windows: identity over-reaches 2x, the packed prefix
/// (max_hot_fraction 0.25 -> 2 MiB) fits.
fn stressed_machine() -> Machine {
    let mut cfg = MachineConfig::tiny_test();
    cfg.tlb.entries = 64;
    cfg.memory.total_bytes = 16 << 20;
    Machine::new(cfg).expect("stressed tiny machine is valid")
}

fn remap_config() -> RemapConfig {
    RemapConfig {
        page_bytes: 1 << 16, // the stressed machine's page
        ..RemapConfig::default()
    }
}

fn quick_batcher() -> BatcherConfig {
    BatcherConfig {
        max_batch_rows: 8_192,
        max_wait: std::time::Duration::from_micros(200),
        max_pending: 4_096,
    }
}

/// Eager escalation for manual epochs: the ladder walks redeal -> resplit
/// (declined, no splitter) -> migrate (declined, single card) -> repack in
/// a handful of epochs instead of minutes of patience.
fn eager_control() -> ControlPlaneConfig {
    ControlPlaneConfig {
        min_imbalance: 0.05,
        patience: 1,
        cooldown: 0,
        max_lever: Lever::Repack, // clamped per backend anyway
        trace_len: 256,
    }
}

fn start_backend(machine: &Machine, table: &Table, remap: bool) -> Arc<SimBackend> {
    let map = TopologyMap::ground_truth(machine);
    let plan = WindowPlan::split(table.rows, ROW_BYTES, WINDOWS);
    let mut cfg = SimBackendConfig::new(PlacementPolicy::GroupToChunk);
    cfg.batcher = quick_batcher();
    cfg.control = eager_control();
    cfg.adaptive = Some(AdaptiveConfig::default());
    cfg.calib_accesses_per_sm = 3_000;
    if remap {
        cfg.remap = Some(remap_config());
    }
    Arc::new(
        SimBackend::start(
            cfg,
            &map,
            plan,
            table.view(),
            SimTiming::machine(machine.clone()),
        )
        .expect("start sim backend"),
    )
}

fn spec(table: &Table, dist: Distribution) -> WorkloadSpec {
    WorkloadSpec {
        total_rows: table.rows,
        distribution: dist,
        request_rows: (ROWS_PER_REQUEST, ROWS_PER_REQUEST),
        seed: 99,
    }
}

/// Drive `warm` convergence requests (epoch after each, so the control
/// plane can learn the hot set and publish a repack), reset the simulated
/// accounting, then drive `measured` requests and return (aggregate GB/s
/// over the measured phase, packed windows live at the end).
fn run_serve_arm(
    backend: &Arc<SimBackend>,
    table: &Table,
    mut gen: RequestGen,
    warm: usize,
    measured: usize,
) -> (f64, usize) {
    let dyn_backend: Arc<dyn Backend> = Arc::clone(backend);
    let service = Service::new(dyn_backend);
    for _ in 0..warm {
        let rows = Arc::new(gen.next_request());
        let out = service.lookup(Arc::clone(&rows)).expect("lookup");
        service.recycle(out);
        backend.rebalance_epoch();
    }
    backend.reset_sim_stats();
    for i in 0..measured {
        let rows = Arc::new(gen.next_request());
        let out = service.lookup(Arc::clone(&rows)).expect("lookup");
        if i % 64 == 0 {
            assert_eq!(out.len(), rows.len() * D, "short response");
            for (k, &row) in rows.iter().enumerate() {
                for j in 0..D {
                    assert_eq!(out[k * D + j], table.expected(row, j), "row {row} col {j}");
                }
            }
        }
        service.recycle(out);
        // Keep epochs ticking so drift arms can re-pack mid-measurement.
        backend.rebalance_epoch();
        backend
            .remap_plan()
            .check(&backend.plan())
            .expect("published remap plan violates invariants");
    }
    let report = backend.sim_report();
    let total_rows: u64 = report.iter().map(|r| r.rows).sum();
    let max_ns = report.iter().map(|r| r.sim_ms * 1e6).fold(0.0f64, f64::max);
    let gbps = if max_ns > 0.0 {
        total_rows as f64 * ROW_BYTES as f64 / max_ns
    } else {
        0.0
    };
    (gbps, backend.remap_plan().packed_windows())
}

/// The DES account: one group reading `region` uniformly; the packed arm's
/// region is the hot prefix, the identity arm's the whole window.
fn layout_measure(machine: &Machine, region: MemRegion, accesses: u64) -> (f64, f64, f64) {
    let map = TopologyMap::ground_truth(machine);
    let mut spec = MeasurementSpec::uniform_all(
        &map.groups[0],
        Pattern::Uniform(region),
        accesses,
        0x9AC4ED,
    );
    spec.txn_bytes = ROW_BYTES;
    let m = machine.run(&spec);
    (m.gbps, m.tlb_hit_rate, m.utlb_hit_rate)
}

fn main() {
    let args: Vec<String> = std::env::args().collect();
    let smoke = args.iter().any(|a| a == "--smoke");

    let machine = stressed_machine();
    let rows = machine.config().memory.total_bytes / ROW_BYTES;
    let table = Table::synthetic(rows, D);
    let window_bytes = rows / WINDOWS as u64 * ROW_BYTES;
    let reach = machine.config().tlb.reach_bytes();
    assert!(
        window_bytes > reach,
        "bench premise broken: window {window_bytes} B must exceed reach {reach} B"
    );

    let (warm, measured) = if smoke { (60, 60) } else { (150, 250) };
    println!(
        "# Layout packing ({}, d={D}, {rows} rows, {WINDOWS} windows of {} MiB, reach {} MiB)",
        if smoke { "smoke" } else { "full" },
        window_bytes >> 20,
        reach >> 20,
    );

    // --- serve arms --------------------------------------------------------
    let arms: &[(&str, Distribution)] = &[
        ("zipf1.1", Distribution::Zipf { theta: 1.1 }),
        (
            "drift-zipf1.1",
            Distribution::Drift {
                inner: Box::new(Distribution::Zipf { theta: 1.1 }),
                period: (warm / 2) as u64,
            },
        ),
        ("uniform", Distribution::Uniform),
    ];
    println!(
        "{:>14} {:>9} {:>12} {:>12} {:>8}",
        "workload", "layout", "gbps", "packed_wins", "ratio"
    );
    let mut serve_rows = Vec::new();
    for (name, dist) in arms {
        let mut gbps_of = [0.0f64; 2];
        let mut packed_of = [0usize; 2];
        for (i, remap) in [false, true].into_iter().enumerate() {
            let backend = start_backend(&machine, &table, remap);
            let gen = RequestGen::new(spec(&table, dist.clone()));
            let (gbps, packed) = run_serve_arm(&backend, &table, gen, warm, measured);
            let m = backend.metrics();
            if remap {
                assert_eq!(
                    m.generations_published,
                    m.redeal_epochs + m.resplit_epochs + m.migrate_epochs + m.repack_epochs,
                    "repartition counters inconsistent"
                );
            }
            backend.shutdown();
            gbps_of[i] = gbps;
            packed_of[i] = packed;
            println!(
                "{:>14} {:>9} {:>12.2} {:>12} {:>8}",
                name,
                if remap { "packed" } else { "identity" },
                gbps,
                packed,
                "-"
            );
        }
        let ratio = gbps_of[1] / gbps_of[0].max(1e-12);
        println!("{:>14} {:>9} {:>12} {:>12} {:>8.2}", name, "ratio", "-", "-", ratio);
        serve_rows.push((*name, gbps_of[0], gbps_of[1], packed_of[1], ratio));
    }

    // --- direct DES layout account ----------------------------------------
    let accesses = if smoke { 2_000 } else { 10_000 };
    let hot_bytes = window_bytes / 4; // max_hot_fraction
    let (id_gbps, id_tlb, id_utlb) = layout_measure(
        &machine,
        MemRegion::new(0, window_bytes),
        accesses,
    );
    let (pk_gbps, pk_tlb, pk_utlb) = layout_measure(
        &machine,
        MemRegion::new(0, hot_bytes),
        accesses,
    );
    println!(
        "# DES layout account: identity window {:.1} GB/s (tlb {:.3}, utlb {:.3}) \
         vs packed prefix {:.1} GB/s (tlb {:.3}, utlb {:.3})",
        id_gbps, id_tlb, id_utlb, pk_gbps, pk_tlb, pk_utlb
    );

    // --- acceptance (full mode only; smoke just emits the numbers) --------
    if !smoke {
        let zipf = serve_rows.iter().find(|r| r.0 == "zipf1.1").unwrap();
        assert!(
            zipf.3 > 0,
            "zipf arm never packed a window: the ratio would be vacuous"
        );
        assert!(
            zipf.4 >= 1.2,
            "packed {:.2} GB/s not >= 1.2x identity {:.2} GB/s under zipf(1.1)",
            zipf.2,
            zipf.1
        );
        let uni = serve_rows.iter().find(|r| r.0 == "uniform").unwrap();
        assert!(
            (uni.4 - 1.0).abs() <= 0.05,
            "uniform parity broken: packed {:.2} vs identity {:.2} GB/s",
            uni.2,
            uni.1
        );
        assert!(
            pk_tlb > id_tlb,
            "packed prefix must improve the TLB hit rate ({pk_tlb:.3} vs {id_tlb:.3})"
        );
    }

    let json = Json::obj(vec![
        ("workload", Json::str("layout_packing")),
        ("smoke", Json::num(if smoke { 1u32 } else { 0u32 })),
        ("d", Json::num(D as u32)),
        ("rows", Json::num(rows as u32)),
        ("windows", Json::num(WINDOWS as u32)),
        ("window_bytes", Json::num(window_bytes as u32)),
        ("reach_bytes", Json::num(reach as u32)),
        (
            "serve",
            Json::arr(
                serve_rows
                    .iter()
                    .map(|&(name, id, pk, packed, ratio)| {
                        Json::obj(vec![
                            ("skew", Json::str(name)),
                            ("identity_gbps", Json::num(id)),
                            ("packed_gbps", Json::num(pk)),
                            ("packed_windows", Json::num(packed as u32)),
                            ("ratio", Json::num(ratio)),
                        ])
                    })
                    .collect(),
            ),
        ),
        (
            "layout",
            Json::arr(vec![
                Json::obj(vec![
                    ("region", Json::str("identity_window")),
                    ("gbps", Json::num(id_gbps)),
                    ("tlb_hit_rate", Json::num(id_tlb)),
                    ("utlb_hit_rate", Json::num(id_utlb)),
                ]),
                Json::obj(vec![
                    ("region", Json::str("packed_prefix")),
                    ("gbps", Json::num(pk_gbps)),
                    ("tlb_hit_rate", Json::num(pk_tlb)),
                    ("utlb_hit_rate", Json::num(pk_utlb)),
                ]),
            ]),
        ),
    ]);
    let path = "BENCH_layout.json";
    match std::fs::write(path, json.to_string_pretty()) {
        Ok(()) => println!("[json] wrote {path}"),
        Err(e) => eprintln!("warning: could not write {path}: {e}"),
    }
}

//! END-TO-END DRIVER: serve batched lookups over a sharded table with
//! TLB-aware (group-to-chunk) placement, on the real three-layer stack:
//!
//!   L1  Pallas gather kernels   (compiled at `make artifacts` time)
//!   L2  JAX lookup/bag model    (same artifacts; python NOT running now)
//!   L3  this Rust coordinator   (batcher -> router -> per-group PJRT
//!                                workers -> ordered merge)
//!
//! The run:
//!   1. probe the simulated card for its resource groups + TLB reach,
//!   2. shard a synthetic embedding table into reach-sized windows,
//!   3. serve concurrent uniform and zipf-skewed clients, reporting
//!      wall-clock latency/throughput per policy,
//!   4. project device time with the DES: what the same workload costs on
//!      the simulated A100 under naive vs group-to-chunk placement,
//!   5. run a few `bag_loss_and_grad` training steps host-side (SGD on the
//!      table) and log the loss curve.
//!
//! Requires `make artifacts`.  Run: `cargo run --release --example embedding_server`

use std::sync::Arc;
use std::time::Instant;

use a100win::config::MachineConfig;
use a100win::coordinator::{
    BatcherConfig, EmbeddingServer, PlacementPolicy, ServerConfig, Table, WindowPlan,
};
use a100win::experiments::common::{ground_truth_map, run_policy};
use a100win::runtime::Runtime;
use a100win::service::Service;
use a100win::sim::Machine;
use a100win::workload::{synth::Distribution, RequestGen, WorkloadSpec};

fn main() -> anyhow::Result<()> {
    let artifacts = Runtime::default_artifacts_dir()?;
    let rt = Runtime::new(&artifacts)?;
    let lookup_meta = rt
        .manifest()
        .first_of("lookup")
        .ok_or_else(|| anyhow::anyhow!("no lookup artifacts"))?;
    let train_meta = rt.manifest().first_of("bag_loss_and_grad");
    drop(rt);

    // --- 1. probe (ground-truth map; `a100win probe` produces the same) ---
    let machine = Machine::new(MachineConfig::a100_80gb()).map_err(anyhow::Error::msg)?;
    let map = ground_truth_map(&machine);
    println!(
        "card: {} SMs in {} resource groups, TLB reach {} GiB",
        machine.topology().sm_count(),
        map.groups.len(),
        map.reach_bytes >> 30
    );

    // --- 2. table + windows ------------------------------------------------
    let windows = 4usize;
    let rows = (lookup_meta.n * windows) as u64;
    let table = Table::synthetic(rows, lookup_meta.d);
    println!(
        "table: {rows} rows x {} f32 = {} MiB in {windows} windows\n",
        lookup_meta.d,
        rows * lookup_meta.d as u64 * 4 >> 20
    );

    // --- 3. serve under both policies ---------------------------------------
    for policy in [PlacementPolicy::Naive, PlacementPolicy::GroupToChunk] {
        serve_one(policy, &artifacts, &map, rows, windows, &table)?;
    }

    // --- 4. device-time projection ------------------------------------------
    println!("device-time projection (DES, 80 GiB table, full SM load):");
    for (name, policy, chunks) in [
        ("naive", PlacementPolicy::Naive, 1),
        ("group-to-chunk", PlacementPolicy::GroupToChunk, 2),
    ] {
        let gbps = run_policy(&machine, &map, policy, 80, chunks, 3_000, 11);
        let us_per_mrow = 1e6 * (1_000_000.0 * 128.0) / (gbps * 1e9);
        println!("  {name:>15}: {gbps:6.0} GB/s -> {us_per_mrow:6.0} µs per 1M-row batch");
    }

    // --- 5. training steps ---------------------------------------------------
    if let Some(meta) = train_meta {
        println!("\ntraining: {} (batch {}, bag {})", meta.name, meta.b, meta.g.unwrap());
        train_demo(&artifacts, &meta)?;
    }
    Ok(())
}

fn serve_one(
    policy: PlacementPolicy,
    artifacts: &std::path::Path,
    map: &a100win::probe::TopologyMap,
    rows: u64,
    windows: usize,
    table: &Table,
) -> anyhow::Result<()> {
    let plan = WindowPlan::split(rows, 128, windows);
    let mut cfg = ServerConfig::new(artifacts.to_path_buf());
    cfg.policy = policy;
    cfg.batcher = BatcherConfig::default();
    // The PJRT server behind the ticketed facade: clients share the
    // Service (cheap clone), submit Arc'd indices, redeem tickets.
    let service = Service::new(Arc::new(EmbeddingServer::start(
        cfg,
        map,
        plan,
        table.view(),
    )?));

    let clients = 6;
    let requests_per_client = 40;
    let rows_per_request = 1024;
    let t = Instant::now();
    let checked: u64 = std::thread::scope(|s| {
        let mut handles = Vec::new();
        for c in 0..clients {
            let service = service.clone();
            let table = table.clone();
            handles.push(s.spawn(move || {
                let dist = if c % 2 == 0 {
                    Distribution::Uniform
                } else {
                    Distribution::ZipfScattered { theta: 0.99 }
                };
                let mut gen = RequestGen::new(WorkloadSpec {
                    total_rows: table.rows,
                    distribution: dist,
                    request_rows: (rows_per_request, rows_per_request),
                    seed: c as u64,
                });
                let mut checked = 0u64;
                for _ in 0..requests_per_client {
                    let req = Arc::new(gen.next_request());
                    let ticket = service.submit(Arc::clone(&req), None).expect("submit");
                    let out = ticket.wait().expect("lookup");
                    // Spot-check correctness on every 97th row.
                    for (i, &r) in req.iter().enumerate().step_by(97) {
                        assert_eq!(out[i * table.d], table.expected(r, 0));
                        checked += 1;
                    }
                }
                checked
            }));
        }
        handles.into_iter().map(|h| h.join().unwrap()).sum()
    });
    let dt = t.elapsed();
    let m = service.metrics();
    println!("policy {policy}:");
    println!(
        "  {} requests x {rows_per_request} rows from {clients} clients in {:.2}s \
         -> {:.0} lookups/s, {:.2} M rows/s ({checked} rows spot-checked)",
        m.requests,
        dt.as_secs_f64(),
        m.requests as f64 / dt.as_secs_f64(),
        m.rows as f64 / dt.as_secs_f64() / 1e6,
    );
    println!("  {}\n", m.report());
    service.shutdown();
    Ok(())
}

/// A few steps of host-side SGD on the table via the AOT fwd+bwd artifact.
fn train_demo(
    artifacts: &std::path::Path,
    meta: &a100win::runtime::ArtifactMeta,
) -> anyhow::Result<()> {
    let mut rt = Runtime::new(artifacts)?;
    let (b, n, d, g) = (meta.b, meta.n, meta.d, meta.g.unwrap());
    rt.ensure_compiled(&meta.name)?;

    // Learn a fixed target function from a fixed batch: loss must fall.
    let mut rng = a100win::util::rng::Rng::seed_from_u64(13);
    let mut table: Vec<f32> = (0..n * d).map(|_| (rng.gen_f64() as f32 - 0.5) * 0.1).collect();
    let indices: Vec<i32> = (0..b * g).map(|_| rng.gen_range(n as u64) as i32).collect();
    let targets: Vec<f32> = (0..b * d).map(|_| rng.gen_f64() as f32).collect();
    let idx_buf = rt.upload_i32(&indices, &[b, g])?;
    let tgt_buf = rt.upload_f32(&targets, &[b, d])?;

    // Mean-loss grads scale as 1/(b*d); compensate in the step size.
    let lr = (b * d) as f32 / 40.0;
    let mut first = None;
    let mut last = 0.0;
    for step in 0..24 {
        let tab_buf = rt.upload_f32(&table, &[n, d])?;
        let outs = rt.execute(&meta.name, &[&idx_buf, &tab_buf, &tgt_buf])?;
        let loss = outs[0].to_vec::<f32>()?[0];
        let grad = outs[1].to_vec::<f32>()?;
        for (w, g_) in table.iter_mut().zip(&grad) {
            *w -= lr * g_;
        }
        if first.is_none() {
            first = Some(loss);
        }
        last = loss;
        println!("  step {step:2}: loss {loss:.6}");
    }
    let first = first.unwrap();
    anyhow::ensure!(last < first * 0.5, "loss did not fall: {first} -> {last}");
    println!("  loss fell {first:.4} -> {last:.4} ✓");
    Ok(())
}

//! Probe a simulated card whose SM enumeration you do not know, render the
//! Fig-2/Fig-3 matrices, and save the TopologyMap artifact.
//!
//! Run with: `cargo run --release --example probe_topology [-- <seed>]`
//!
//! Try different seeds: the enumeration (and thus Fig 2) changes per card,
//! the discovered *structure* (14 groups of 6/8) does not.

use a100win::config::MachineConfig;
use a100win::probe::{cluster, pair_probe, ProbeConfig, Prober};
use a100win::sim::Machine;

fn main() -> anyhow::Result<()> {
    let seed: u64 = std::env::args()
        .nth(1)
        .map(|s| s.parse())
        .transpose()?
        .unwrap_or(0xD1E);

    let mut cfg = MachineConfig::a100_80gb();
    cfg.topology.smid_permutation_seed = seed;
    let machine = Machine::new(cfg).map_err(anyhow::Error::msg)?;
    println!(
        "card seed {seed:#x}: {} SMs (grouping unknown to the prober)\n",
        machine.topology().sm_count()
    );

    // Fig 2: raw pair matrix in smid order.
    let mut pc = ProbeConfig::for_machine(&machine);
    pc.pair.accesses_per_sm = 1_000;
    pc.verify.accesses_per_sm = 2_500;
    let t = std::time::Instant::now();
    let matrix = pair_probe(&machine, &pc.pair);
    println!(
        "Fig 2 — pair matrix, smid order ({} runs in {:.1}s):",
        matrix.n * (matrix.n + 1) / 2,
        t.elapsed().as_secs_f64()
    );
    let ident: Vec<usize> = (0..matrix.n).collect();
    print!("{}", matrix.render(&ident));

    // Fig 3: rearranged.
    let clustering = cluster(&matrix);
    println!("\nFig 3 — same matrix, indices rearranged by discovered group:");
    print!("{}", matrix.render(&clustering.permutation));
    println!();
    for (gid, members) in clustering.groups.iter().enumerate() {
        println!("group {gid:2}: {:2} SMs {members:?}", members.len());
    }

    // Full pipeline (adds Figs 4-5 verification + reach sweep) and artifact.
    let outcome = Prober::with_config(&machine, pc).run()?;
    let path = std::path::PathBuf::from(format!("topomap-{seed:#x}.json"));
    outcome.map.save(&path)?;
    println!(
        "\nreach ~{} GiB, independent: {} -> wrote {}",
        outcome.map.reach_bytes >> 30,
        outcome.map.independent,
        path.display()
    );
    Ok(())
}

//! Quickstart: the paper's story in five minutes.
//!
//! 1. Build the simulated A100 and show the problem (Fig 1's cliff).
//! 2. Probe the card to discover its SM resource groups (Figs 2-3).
//! 3. Apply group-to-chunk placement and show full speed at 80 GiB (Fig 6).
//!
//! Run with: `cargo run --release --example quickstart`

use a100win::config::{MachineConfig, GIB};
use a100win::coordinator::{Placement, PlacementPolicy, WindowPlan};
use a100win::probe::{ProbeConfig, Prober};
use a100win::sim::{Machine, MeasurementSpec, MemRegion, Pattern};

fn main() -> anyhow::Result<()> {
    // --- 1. the problem ---------------------------------------------------
    let machine = Machine::new(MachineConfig::a100_80gb()).map_err(anyhow::Error::msg)?;
    let sms = machine.topology().all_sms();
    println!("simulated A100-SXM4-80GB: {} SMs", sms.len());

    let run_uniform = |gib: u64| {
        let spec = MeasurementSpec::uniform_all(
            &sms,
            Pattern::Uniform(MemRegion::new(0, gib * GIB)),
            3_000,
            1,
        );
        machine.run(&spec).gbps
    };
    let at32 = run_uniform(32);
    let at80 = run_uniform(80);
    println!("random 128 B reads over 32 GiB: {at32:6.0} GB/s");
    println!("random 128 B reads over 80 GiB: {at80:6.0} GB/s   <- the cliff (TLB reach is 64 GiB)");

    // --- 2. probe the card ------------------------------------------------
    println!("\nprobing SM pairs to find the shared translation domains...");
    let mut pc = ProbeConfig::for_machine(&machine);
    pc.pair.accesses_per_sm = 1_000; // quick demo settings
    pc.verify.accesses_per_sm = 2_500;
    let outcome = Prober::with_config(&machine, pc).run()?;
    println!(
        "discovered {} resource groups (sizes {:?}), reach ~{} GiB, independent: {}",
        outcome.map.groups.len(),
        outcome.map.groups.iter().map(|g| g.len()).collect::<Vec<_>>(),
        outcome.map.reach_bytes / GIB,
        outcome.map.independent,
    );

    // --- 3. the fix ---------------------------------------------------------
    let row_bytes = 128u64;
    let total_rows = machine.config().memory.total_bytes / row_bytes;
    let plan = WindowPlan::for_reach(
        total_rows,
        row_bytes,
        outcome.map.reach_bytes,
        outcome.map.groups.len(),
    )?;
    let placement = Placement::build(PlacementPolicy::GroupToChunk, &outcome.map, &plan, 0)?;
    let spec = MeasurementSpec {
        assignments: placement.sim_assignments(&outcome.map, &plan, &machine, 2),
        accesses_per_sm: 3_000,
        warmup_fraction: 0.25,
        txn_bytes: 128,
        seed: 2,
    };
    let fixed = machine.run(&spec).gbps;
    println!(
        "\ngroup-to-chunk over all 80 GiB ({} windows): {fixed:6.0} GB/s  ({:.1}x the naive 80 GiB run)",
        plan.count(),
        fixed / at80
    );
    println!("full-speed random access to the entire memory. ∎");
    Ok(())
}

//! Fleet sharding: a table too big for one card, spread across a mixed
//! fleet where every card has a *different* probed layout (the paper:
//! smid->group mapping "may vary card to card").
//!
//! Probes three simulated cards (different enumeration seeds, one with only
//! 40 GiB), builds a capacity-weighted fleet plan, verifies every card's
//! windows sit inside its own probed reach, and routes a batch end to end:
//! global row -> card -> window -> SM group.
//!
//! Run: `cargo run --release --example fleet_sharding`

use std::sync::Arc;

use a100win::config::{MachineConfig, GIB};
use a100win::coordinator::{BatcherConfig, CardSpec, FleetPlan, Table};
use a100win::probe::{ProbeConfig, Prober};
use a100win::service::{FleetService, SimTiming};
use a100win::sim::Machine;
use a100win::util::rng::Rng;

fn probe_card(seed: u64, memory_gib: u64) -> anyhow::Result<CardSpec> {
    let mut cfg = MachineConfig::a100_80gb();
    cfg.topology.smid_permutation_seed = seed;
    cfg.memory.total_bytes = memory_gib * GIB;
    let machine = Machine::new(cfg).map_err(anyhow::Error::msg)?;
    let mut pc = ProbeConfig::for_machine(&machine);
    pc.pair.accesses_per_sm = 800; // quick demo probe
    pc.verify.accesses_per_sm = 2_000;
    let t = std::time::Instant::now();
    let outcome = Prober::with_config(&machine, pc).run()?;
    println!(
        "card seed {seed:#x} ({memory_gib} GiB): {} groups, reach ~{} GiB, \
         capacity {:.0} GB/s (probed in {:.1}s)",
        outcome.map.groups.len(),
        outcome.map.reach_bytes >> 30,
        outcome.map.solo_gbps.iter().sum::<f64>(),
        t.elapsed().as_secs_f64()
    );
    Ok(CardSpec {
        map: outcome.map,
        memory_bytes: memory_gib * GIB,
    })
}

fn main() -> anyhow::Result<()> {
    println!("probing the fleet...");
    let cards = vec![
        probe_card(0xA, 80)?,
        probe_card(0xB, 80)?,
        probe_card(0xC, 40)?, // the 40 GB launch variant
    ];

    // Check the card-to-card variation the paper warns about: the group
    // containing smid 0 differs between cards.
    let g0 = |c: &CardSpec| c.map.groups[c.map.group_of(0).unwrap()].clone();
    println!(
        "\nsmid 0's group on card A: {:?}\nsmid 0's group on card B: {:?}",
        g0(&cards[0]),
        g0(&cards[1])
    );

    // A 150 GiB table: needs all three cards.
    let total_rows = 150 * GIB / 128;
    let plan = FleetPlan::build(&cards, total_rows, 128, 0)?;
    println!("\nfleet plan for a 150 GiB table ({total_rows} rows):");
    for s in &plan.shards {
        println!(
            "  card {}: rows [{}, {}) = {} GiB in {} windows (each <= reach)",
            s.card,
            s.start_row,
            s.end_row(),
            s.rows * 128 / GIB,
            s.plan.count()
        );
    }
    anyhow::ensure!(plan.fits_reach(&cards), "reach invariant violated");

    // Route a request batch end to end.
    let mut rng = Rng::seed_from_u64(9);
    let batch: Vec<u64> = (0..10_000).map(|_| rng.gen_range(total_rows)).collect();
    let split = plan.split(&batch)?;
    println!("\nrouting 10k random rows:");
    for (si, (locals, _pos)) in split.iter().enumerate() {
        let shard = &plan.shards[si];
        // Second level: window + group within the card.
        let mut per_window = vec![0usize; shard.plan.count()];
        for &l in locals {
            per_window[shard.plan.window_of(l).id] += 1;
        }
        println!(
            "  card {}: {} rows, per-window {:?}, serving groups {:?}",
            shard.card,
            locals.len(),
            per_window,
            (0..shard.plan.count())
                .map(|w| shard.placement.serving_groups(w).to_vec())
                .collect::<Vec<_>>()
        );
    }
    let covered: usize = split.iter().map(|(l, _)| l.len()).sum();
    anyhow::ensure!(covered == batch.len());
    println!("\nall rows routed; every window within its card's probed reach. ∎");

    // --- actually serve through the fleet facade (scaled-down table) ------
    // The 150 GiB plan above is routing-only; here a host-resident table is
    // sharded across the same probed cards and served end to end: tickets
    // per card, rows merged back in request order.
    println!("\nserving a scaled-down table through service::FleetService...");
    let rows = 300_000u64;
    let table = Table::synthetic(rows, 32);
    let specs: Vec<(CardSpec, SimTiming)> = cards
        .iter()
        .map(|c| (c.clone(), SimTiming::Probed))
        .collect();
    let fleet = FleetService::build_sim(specs, &table, BatcherConfig::default(), 0)?;
    let mut served = 0u64;
    for i in 0..20u64 {
        let req: Arc<Vec<u64>> =
            Arc::new((0..2_000).map(|_| rng.gen_range(rows)).collect());
        let out = fleet.submit(Arc::clone(&req), None)?.wait()?;
        for (k, &r) in req.iter().enumerate().step_by(211) {
            anyhow::ensure!(
                out[k * table.d] == table.expected(r, 0),
                "request {i}: row {r} mismatched"
            );
        }
        served += req.len() as u64;
    }
    println!("served {served} rows, merged in request order; per-card metrics:");
    for (card, m) in fleet.per_card_metrics() {
        println!("  card {card}: {}", m.report());
    }
    fleet.shutdown();
    Ok(())
}

//! TLB explorer: poke the simulated memory hierarchy with different access
//! patterns and watch hit rates, walk counts, and throughput respond.
//!
//! Run: `cargo run --release --example tlb_explorer [-- <region_gib>]`

use a100win::config::{MachineConfig, GIB};
use a100win::sim::{Machine, MeasurementSpec, MemRegion, Pattern};

fn main() -> anyhow::Result<()> {
    let focus_gib: u64 = std::env::args()
        .nth(1)
        .map(|s| s.parse())
        .transpose()?
        .unwrap_or(72);

    let machine = Machine::new(MachineConfig::a100_80gb()).map_err(anyhow::Error::msg)?;
    let sms = machine.topology().all_sms();
    let cfg = machine.config();
    println!(
        "A100-80GB sim: group TLB {} entries x {} MiB pages = {} GiB reach, {} walkers/group\n",
        cfg.tlb.entries,
        cfg.tlb.page_bytes >> 20,
        cfg.tlb.reach_bytes() / GIB,
        cfg.tlb.walkers_per_group
    );

    println!("== region sweep (uniform random, all SMs) ==");
    println!(
        "{:>10} {:>10} {:>9} {:>12} {:>12} {:>10}",
        "region_gib", "GB/s", "hit_rate", "walks", "merged", "lat_ns"
    );
    for gib in [8u64, 32, 56, 64, 68, 72, 80] {
        let meas = machine.run(&MeasurementSpec::uniform_all(
            &sms,
            Pattern::Uniform(MemRegion::new(0, gib * GIB)),
            3_000,
            gib,
        ));
        println!(
            "{gib:>10} {:>10.0} {:>9.3} {:>12} {:>12} {:>10.0}",
            meas.gbps,
            meas.tlb_hit_rate,
            meas.walks(),
            meas.merged_walks(),
            meas.avg_latency_ns
        );
    }

    println!("\n== pattern comparison over {focus_gib} GiB ==");
    let region = MemRegion::new(0, focus_gib * GIB);
    let patterns: Vec<(&str, Pattern)> = vec![
        ("uniform", Pattern::Uniform(region)),
        ("sequential", Pattern::Sequential(region)),
        (
            "strided_64",
            Pattern::Strided {
                region,
                stride_lines: 64,
            },
        ),
        (
            "zipf_0.99",
            Pattern::Zipf {
                region,
                theta: 0.99,
            },
        ),
    ];
    println!(
        "{:>12} {:>10} {:>9} {:>10} {:>10}",
        "pattern", "GB/s", "tlb_hit", "utlb_hit", "lat_ns"
    );
    for (name, p) in patterns {
        let meas = machine.run(&MeasurementSpec::uniform_all(&sms, p, 3_000, 99));
        println!(
            "{name:>12} {:>10.0} {:>9.3} {:>10.3} {:>10.0}",
            meas.gbps, meas.tlb_hit_rate, meas.utlb_hit_rate, meas.avg_latency_ns
        );
    }

    println!("\n== per-group view at {focus_gib} GiB (uniform) ==");
    let meas = machine.run(&MeasurementSpec::uniform_all(
        &sms,
        Pattern::Uniform(region),
        3_000,
        5,
    ));
    println!(
        "{:>6} {:>5} {:>9} {:>9} {:>10}",
        "group", "sms", "GB/s", "hit_rate", "walks"
    );
    for g in &meas.per_group {
        println!(
            "{:>6} {:>5} {:>9.1} {:>9.3} {:>10}",
            g.group,
            g.active_sms,
            g.gbps,
            g.tlb_hit_rate(),
            g.walks
        );
    }
    Ok(())
}

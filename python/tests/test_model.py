"""L2 correctness: model entry points, custom VJP, and jit-lowerability."""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model
from compile.kernels import ref as R


@pytest.fixture(scope="module")
def data():
    rng = np.random.default_rng(42)
    n, d, b, g = 512, 32, 256, 8
    table = jnp.asarray(rng.standard_normal((n, d)).astype(np.float32))
    idx = jnp.asarray(rng.integers(0, n, size=(b,), dtype=np.int32))
    bag_idx = jnp.asarray(rng.integers(0, n, size=(b, g), dtype=np.int32))
    targets = jnp.asarray(rng.standard_normal((b, d)).astype(np.float32))
    return table, idx, bag_idx, targets


def test_lookup_tuple_shape(data):
    table, idx, _, _ = data
    (out,) = model.lookup(idx, table)
    assert out.shape == (idx.shape[0], table.shape[1])
    np.testing.assert_array_equal(np.asarray(out), np.asarray(R.gather_rows_ref(idx, table)))


def test_windowed_lookup(data):
    table, idx, _, _ = data
    window = jnp.asarray([64, 128], dtype=jnp.int32)
    (out,) = model.windowed_lookup(window, idx, table)
    np.testing.assert_array_equal(
        np.asarray(out), np.asarray(R.windowed_gather_ref(window, idx, table))
    )


def test_bag_forward(data):
    table, _, bag_idx, _ = data
    (out,) = model.bag_forward(bag_idx, table)
    np.testing.assert_allclose(
        np.asarray(out), np.asarray(R.bag_gather_sum_ref(bag_idx, table)), rtol=1e-5
    )


def test_bag_grad_matches_finite_difference():
    """Custom VJP (pallas fwd + scatter-add bwd) vs numerical gradient.

    Small problem so the loss perturbation stays well above f32 resolution.
    """
    rng = np.random.default_rng(0)
    n, d, b, g = 16, 4, 4, 2
    table = jnp.asarray(rng.standard_normal((n, d)).astype(np.float32))
    bag_idx = jnp.asarray(rng.integers(0, n, size=(b, g), dtype=np.int32))
    targets = jnp.asarray(rng.standard_normal((b, d)).astype(np.float32))
    loss, grad = model.bag_loss_and_grad(bag_idx, table, targets)
    assert grad.shape == table.shape
    tab_np = np.asarray(table)
    eps = 1e-2
    used = np.unique(np.asarray(bag_idx))
    for i in used[:4]:
        for j in range(d):
            tp, tm = tab_np.copy(), tab_np.copy()
            tp[i, j] += eps
            tm[i, j] -= eps
            lp = model.bag_loss(bag_idx, jnp.asarray(tp), targets)
            lm = model.bag_loss(bag_idx, jnp.asarray(tm), targets)
            fd = (float(lp) - float(lm)) / (2 * eps)
            np.testing.assert_allclose(float(grad[i, j]), fd, rtol=5e-2, atol=5e-3)


def test_bag_grad_matches_ref_vjp(data):
    """VJP against the all-jnp reference implementation's autodiff."""
    table, _, bag_idx, targets = data

    def ref_loss(tab):
        out = R.bag_gather_sum_ref(bag_idx, tab)
        diff = out - targets
        return jnp.mean(diff * diff)

    want = jax.grad(ref_loss)(table)
    _, got = model.bag_loss_and_grad(bag_idx, table, targets)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-4, atol=1e-6)


def test_gradient_zero_for_untouched_rows(data):
    table, _, _, _ = data
    bag_idx = jnp.zeros((16, 4), dtype=jnp.int32)  # only row 0 touched
    targets = jnp.zeros((16, table.shape[1]), dtype=jnp.float32)
    _, grad = model.bag_loss_and_grad(bag_idx, table, targets)
    g = np.asarray(grad)
    assert np.any(g[0] != 0)
    assert np.all(g[1:] == 0)


@pytest.mark.parametrize(
    "fn,args_shape",
    [
        ("lookup", "gather"),
        ("windowed_lookup", "windowed"),
        ("bag_forward", "bag"),
        ("bag_loss_and_grad", "train"),
    ],
)
def test_entry_points_jit_lower(data, fn, args_shape):
    """Every AOT entry point must lower under jax.jit (the aot.py path)."""
    table, idx, bag_idx, targets = data
    f = getattr(model, fn)
    if args_shape == "gather":
        args = (idx, table)
    elif args_shape == "windowed":
        args = (jnp.asarray([0, 8], dtype=jnp.int32), idx, table)
    elif args_shape == "bag":
        args = (bag_idx, table)
    else:
        args = (bag_idx, table, targets)
    lowered = jax.jit(f).lower(*args)
    assert lowered.compiler_ir("stablehlo") is not None

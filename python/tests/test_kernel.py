"""L1 correctness: Pallas kernels vs pure-jnp oracles.

Hypothesis sweeps shapes, dtypes-compatible index ranges, and window
placements; every property asserts exact equality (gather is a copy) or
tight allclose (bag sum reassociates adds).
"""

from __future__ import annotations

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import gather as K
from compile.kernels import ref as R

settings.register_profile("ci", max_examples=40, deadline=None)
settings.load_profile("ci")


def make_table(rng: np.random.Generator, n: int, d: int) -> jnp.ndarray:
    return jnp.asarray(rng.standard_normal((n, d)).astype(np.float32))


@st.composite
def gather_case(draw):
    n = draw(st.sampled_from([8, 64, 257, 1024]))
    d = draw(st.sampled_from([1, 4, 32]))
    b = draw(st.sampled_from([1, 8, 96, 256]))
    seed = draw(st.integers(0, 2**31 - 1))
    return n, d, b, seed


@given(gather_case())
def test_gather_matches_ref(case):
    n, d, b, seed = case
    rng = np.random.default_rng(seed)
    table = make_table(rng, n, d)
    idx = jnp.asarray(rng.integers(0, n, size=(b,), dtype=np.int32))
    got = K.gather_rows(idx, table, block_b=min(b, 32) if b % 32 == 0 or b < 32 else b)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(R.gather_rows_ref(idx, table)))


@given(gather_case(), st.integers(0, 2**31 - 1))
def test_windowed_gather_matches_ref(case, wseed):
    n, d, b, seed = case
    rng = np.random.default_rng(seed)
    wrng = np.random.default_rng(wseed)
    table = make_table(rng, n, d)
    # indices may exceed n: the kernel must remap them into the window.
    idx = jnp.asarray(rng.integers(0, 2**30, size=(b,), dtype=np.int32))
    size = int(wrng.integers(1, n + 1))
    base = int(wrng.integers(0, n - size + 1))
    window = jnp.asarray([base, size], dtype=np.int32)
    got = K.windowed_gather(window, idx, table, block_b=b)
    want = R.windowed_gather_ref(window, idx, table)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@given(gather_case(), st.sampled_from([1, 2, 8]))
def test_bag_gather_sum_matches_ref(case, bag):
    n, d, b, seed = case
    rng = np.random.default_rng(seed)
    table = make_table(rng, n, d)
    idx = jnp.asarray(rng.integers(0, n, size=(b, bag), dtype=np.int32))
    got = K.bag_gather_sum(idx, table, block_b=b)
    want = R.bag_gather_sum_ref(idx, table)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-5)


def test_windowed_gather_never_leaves_window():
    """The paper's invariant: accesses stay inside [base, base+size)."""
    rng = np.random.default_rng(7)
    n, d = 512, 32
    # Table whose row i has constant value i -> outputs reveal accessed rows.
    table = jnp.asarray(np.repeat(np.arange(n, dtype=np.float32)[:, None], d, axis=1))
    idx = jnp.asarray(rng.integers(0, 2**31 - 1, size=(256,), dtype=np.int32))
    base, size = 128, 64
    out = K.windowed_gather(jnp.asarray([base, size], dtype=np.int32), idx, table)
    rows = np.asarray(out)[:, 0].astype(np.int64)
    assert rows.min() >= base
    assert rows.max() < base + size


def test_gather_block_divisibility_error():
    table = jnp.zeros((16, 4), jnp.float32)
    idx = jnp.zeros((10,), jnp.int32)
    with pytest.raises(ValueError, match="not divisible"):
        K.gather_rows(idx, table, block_b=4)


def test_gather_default_block_small_batch():
    """batch < DEFAULT_BLOCK_B must still work (block clamps to batch)."""
    rng = np.random.default_rng(3)
    table = make_table(rng, 32, 8)
    idx = jnp.asarray(rng.integers(0, 32, size=(5,), dtype=np.int32))
    np.testing.assert_array_equal(
        np.asarray(K.gather_rows(idx, table)), np.asarray(R.gather_rows_ref(idx, table))
    )


def test_gather_duplicate_indices():
    rng = np.random.default_rng(5)
    table = make_table(rng, 64, 32)
    idx = jnp.asarray(np.full((128,), 17, dtype=np.int32))
    out = np.asarray(K.gather_rows(idx, table))
    np.testing.assert_array_equal(out, np.tile(np.asarray(table)[17], (128, 1)))


def test_bag_single_element_bag_equals_gather():
    rng = np.random.default_rng(11)
    table = make_table(rng, 128, 16)
    idx = jnp.asarray(rng.integers(0, 128, size=(64,), dtype=np.int32))
    bag_out = K.bag_gather_sum(idx[:, None], table)
    gather_out = K.gather_rows(idx, table)
    np.testing.assert_array_equal(np.asarray(bag_out), np.asarray(gather_out))


@given(gather_case())
def test_loop_and_vectorized_bodies_agree(case):
    """The TPU-shaped fori_loop body and the vectorized body are the same op."""
    n, d, b, seed = case
    rng = np.random.default_rng(seed)
    table = make_table(rng, n, d)
    idx = jnp.asarray(rng.integers(0, n, size=(b,), dtype=np.int32))
    fast = K.gather_rows(idx, table)
    slow = K.gather_rows(idx, table, use_loop=True)
    np.testing.assert_array_equal(np.asarray(fast), np.asarray(slow))
    win = jnp.asarray([n // 4, max(n // 2, 1)], dtype=np.int32)
    np.testing.assert_array_equal(
        np.asarray(K.windowed_gather(win, idx, table)),
        np.asarray(K.windowed_gather(win, idx, table, use_loop=True)),
    )


def test_bag_loop_and_vectorized_agree():
    rng = np.random.default_rng(17)
    table = make_table(rng, 256, 32)
    idx = jnp.asarray(rng.integers(0, 256, size=(64, 8), dtype=np.int32))
    np.testing.assert_allclose(
        np.asarray(K.bag_gather_sum(idx, table)),
        np.asarray(K.bag_gather_sum(idx, table, use_loop=True)),
        rtol=1e-6,
        atol=1e-6,
    )

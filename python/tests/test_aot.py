"""AOT path: HLO text emission, manifest integrity, and numeric round-trip.

The round-trip test compiles the emitted HLO text with the local CPU PJRT
client (the same thing the Rust runtime does via the xla crate) and checks
numerics against the oracle — this is the python half of the interchange
contract.
"""

from __future__ import annotations

import json
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax._src.lib import xla_client as xc

from compile import aot, model
from compile.kernels import ref as R


def test_to_hlo_text_contains_entry():
    idx = jax.ShapeDtypeStruct((8,), jnp.int32)
    tab = jax.ShapeDtypeStruct((16, 4), jnp.float32)
    text = aot.to_hlo_text(jax.jit(model.lookup).lower(idx, tab))
    assert "ENTRY" in text
    assert "f32[16,4]" in text
    assert "s32[8]" in text  # indices operand survives lowering


def test_build_entries_complete():
    entries = list(aot.build_entries(1024, 32, (16, 64), 4))
    names = [e[0] for e in entries]
    # 3 kernels x 2 batch sizes + 1 train step
    assert len(names) == 7
    assert any(n.startswith("gather_") for n in names)
    assert any(n.startswith("windowed_gather_") for n in names)
    assert any(n.startswith("bag_fwd_") for n in names)
    assert sum(n.startswith("bag_train_") for n in names) == 1
    for _, _, example_args, meta in entries:
        assert len(example_args) == len(meta["operands"])


def test_cli_writes_artifacts_and_manifest(tmp_path):
    out = tmp_path / "artifacts"
    subprocess.run(
        [sys.executable, "-m", "compile.aot", "--out-dir", str(out), "--n", "256"],
        check=True,
        cwd=str(__import__("pathlib").Path(__file__).resolve().parents[1]),
    )
    manifest = json.loads((out / "manifest.json").read_text())
    assert manifest["version"] == 1
    assert len(manifest["artifacts"]) == 10
    for art in manifest["artifacts"]:
        text = (out / art["file"]).read_text()
        assert text.startswith("HloModule")
        assert art["n"] == 256


@pytest.mark.parametrize("b", [16, 64])
def test_hlo_text_roundtrip_numerics(b):
    """Emit HLO text -> parse back -> instruction ids fit in 32 bits.

    (Full compile-and-execute of the text happens on the Rust side —
    rust/tests/runtime_roundtrip.rs — since jaxlib's in-process compile API
    is not stable across versions.  Here we verify the two properties the
    Rust loader depends on: the text parses as an HloModule, and the jitted
    source function is numerically equal to the oracle.)
    """
    n, d = 128, 32
    rng = np.random.default_rng(b)
    table = rng.standard_normal((n, d)).astype(np.float32)
    idx = rng.integers(0, n, size=(b,), dtype=np.int32)

    lowered = jax.jit(model.lookup).lower(
        jax.ShapeDtypeStruct((b,), jnp.int32), jax.ShapeDtypeStruct((n, d), jnp.float32)
    )
    text = aot.to_hlo_text(lowered)
    # Parse back from TEXT (what the Rust side does), not from the proto.
    parsed = xc._xla.hlo_module_from_text(text)
    assert parsed is not None
    assert "ENTRY" in parsed.to_string()

    (got,) = jax.jit(model.lookup)(jnp.asarray(idx), jnp.asarray(table))
    np.testing.assert_array_equal(
        np.asarray(got), np.asarray(R.gather_rows_ref(jnp.asarray(idx), jnp.asarray(table)))
    )


def test_windowed_artifact_window_operand_first():
    """Runtime contract: windowed executables take window as operand 0."""
    for name, _, example_args, meta in aot.build_entries(512, 32, (16,), 4):
        if meta["entry"] == "windowed_lookup":
            assert meta["operands"][0] == "window"
            assert example_args[0].shape == (2,)

"""L2: the JAX compute graph the Rust coordinator executes via PJRT.

The "model" for a random-access paper is the lookup workload itself:

- ``lookup``            plain row gather (unconstrained benchmark access).
- ``windowed_lookup``   gather constrained to a window — the executable the
                        coordinator runs per SM-resource-group shard
                        (group-to-chunk placement reaches the kernel through
                        the ``window`` operand, so ONE executable serves any
                        placement).
- ``bag_forward``       fixed-size embedding-bag pooling (the application
                        workload the paper's intro motivates: random bag
                        lookups over a table far larger than TLB reach).
- ``bag_loss_and_grad`` fwd+bwd: MSE against targets, gradient w.r.t. the
                        table via a custom VJP whose forward is the Pallas
                        kernel and whose backward is the scatter-add oracle.
                        Demonstrates the kernel composing with jax.grad and
                        gives the coordinator a training-step executable.

Everything here is lowered ONCE by aot.py to HLO text; python never runs on
the request path.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from compile.kernels import gather as K
from compile.kernels import ref as R


def lookup(indices: jax.Array, table: jax.Array) -> tuple[jax.Array]:
    """Unconstrained row gather.  Returns a 1-tuple (AOT convention)."""
    return (K.gather_rows(indices, table),)


def windowed_lookup(window: jax.Array, indices: jax.Array, table: jax.Array) -> tuple[jax.Array]:
    """Window-constrained gather; ``window=[base,size]`` rows."""
    return (K.windowed_gather(window, indices, table),)


@functools.partial(jax.custom_vjp, nondiff_argnums=())
def _bag(indices: jax.Array, table: jax.Array) -> jax.Array:
    return K.bag_gather_sum(indices, table)


def _bag_fwd(indices, table):
    return K.bag_gather_sum(indices, table), (indices, table.shape[0])


def _bag_bwd(res, g):
    indices, n_rows = res
    return (None, R.bag_grad_table_ref(indices, g, n_rows))


_bag.defvjp(_bag_fwd, _bag_bwd)


def bag_forward(indices: jax.Array, table: jax.Array) -> tuple[jax.Array]:
    """Embedding-bag pooling: (B, G) indices -> (B, D) pooled rows."""
    return (_bag(indices, table),)


def bag_loss(indices: jax.Array, table: jax.Array, targets: jax.Array) -> jax.Array:
    out = _bag(indices, table)
    diff = out - targets
    return jnp.mean(diff * diff)


def bag_loss_and_grad(
    indices: jax.Array, table: jax.Array, targets: jax.Array
) -> tuple[jax.Array, jax.Array]:
    """Returns (scalar loss, d loss / d table).  The coordinator's train step."""
    loss, grad = jax.value_and_grad(bag_loss, argnums=1)(indices, table, targets)
    return (loss, grad)

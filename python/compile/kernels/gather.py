"""L1 Pallas kernels: the paper's benchmark access pattern as compiled compute.

The paper's CUDA micro-benchmark has every warp read random coalesced
128-byte lines (32 x 32-bit words) from HBM.  The compiled-compute analogue
is a *row gather*: ``indices[B] x table[N, D=32] f32 -> out[B, D]`` — each
gathered row is exactly one 128-byte cache line.

Three kernels:

- ``gather_rows``        plain row gather (the unconstrained benchmark).
- ``windowed_gather``    row gather with every index remapped into a
                         ``[base, base+size)`` row window.  This is the
                         in-kernel embodiment of the paper's technique: the
                         L3 coordinator assigns each SM resource group a
                         <64 GB window and the kernel *cannot* stray out of
                         it.  ``window = [base, size]`` arrives as a tiny
                         i32 operand so the same executable serves any
                         window placement.
- ``bag_gather_sum``     fixed-size embedding-bag pooling:
                         ``indices[B, G] -> sum_g table[idx[b,g]] : [B, D]``
                         (the "realistic application" workload: random bag
                         lookups over a huge table).

All kernels are lowered with ``interpret=True`` — real-TPU Pallas emits a
Mosaic custom-call the CPU PJRT plugin cannot run (see
/opt/xla-example/README.md).  Hardware adaptation (DESIGN.md
§Hardware-Adaptation): instead of porting warp/threadblock structure, the
grid is blocked over B (the batch of line reads) so each grid step's block
of rows is a VMEM-resident tile; the HBM->VMEM schedule that CUDA expressed
with threadblocks is expressed with the grid + BlockSpec here.

Two kernel bodies per op (EXPERIMENTS.md §Perf):

- the default **vectorized** body gathers the whole index block with one
  ``jnp.take`` — interpret-mode lowers it to a single HLO ``gather`` that
  the CPU backend executes ~50x faster than a loop;
- the ``use_loop=True`` body walks the block with ``fori_loop`` +
  dynamic-slice loads — the shape a real-TPU lowering wants when the table
  cannot be materialized in VMEM.  pytest asserts both bodies agree.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Default tile of gather indices handled by one grid step.  256 rows x 32
# f32 = 32 KiB out-tile: comfortably VMEM-resident alongside the index
# vector.
DEFAULT_BLOCK_B = 256


def _gather_block(idx, table_ref, o_ref, *, block_b: int, use_loop: bool):
    """Copy ``table[idx[i], :]`` into ``o_ref[i, :]`` for each row of the block.

    Vectorized body (default): one ``jnp.take`` over the block — interpret
    mode lowers it to a single HLO ``gather``.  Loop body: dynamic-slice
    loads inside a fori_loop — the shape a real-TPU lowering needs when the
    table must stay in HBM/ANY (EXPERIMENTS.md §Perf compares them).
    """
    d = o_ref.shape[1]
    if not use_loop:
        o_ref[...] = jnp.take(table_ref[...], idx, axis=0)
        return

    def body(i, _):
        r = idx[i]
        row = pl.load(table_ref, (pl.dslice(r, 1), pl.dslice(0, d)))
        pl.store(o_ref, (pl.dslice(i, 1), pl.dslice(0, d)), row)
        return 0

    jax.lax.fori_loop(0, block_b, body, 0)


def _gather_kernel(idx_ref, table_ref, o_ref, *, block_b: int, use_loop: bool):
    _gather_block(idx_ref[...], table_ref, o_ref, block_b=block_b, use_loop=use_loop)


def _windowed_gather_kernel(
    window_ref, idx_ref, table_ref, o_ref, *, block_b: int, use_loop: bool
):
    base = window_ref[0]
    size = window_ref[1]
    # Remap every index into [base, base+size).  `% size` (not clamp) keeps
    # the access stream uniform over the window, matching the paper's
    # benchmark which draws uniformly inside the restricted region.
    idx = base + jax.lax.rem(idx_ref[...], size)
    _gather_block(idx, table_ref, o_ref, block_b=block_b, use_loop=use_loop)


def _bag_kernel(idx_ref, table_ref, o_ref, *, block_b: int, bag: int, use_loop: bool):
    d = o_ref.shape[1]
    idx = idx_ref[...]  # (block_b, bag)
    if not use_loop:
        # (block, bag, d) gather then reduce over the bag axis: lowers to
        # one HLO gather + reduce, fused by XLA.
        rows = jnp.take(table_ref[...], idx.reshape(-1), axis=0)
        o_ref[...] = rows.reshape((block_b, bag, d)).sum(axis=1)
        return

    def body(i, _):
        def inner(g, acc):
            r = idx[i, g]
            row = pl.load(table_ref, (pl.dslice(r, 1), pl.dslice(0, d)))
            return acc + row.reshape((d,))

        acc = jax.lax.fori_loop(0, bag, inner, jnp.zeros((d,), o_ref.dtype))
        pl.store(o_ref, (pl.dslice(i, 1), pl.dslice(0, d)), acc.reshape((1, d)))
        return 0

    jax.lax.fori_loop(0, block_b, body, 0)


def _block_b_for(batch: int, requested: int | None) -> int:
    block = requested or DEFAULT_BLOCK_B
    if batch < block:
        block = batch
    if batch % block != 0:
        raise ValueError(f"batch {batch} not divisible by block_b {block}")
    return block


def gather_rows(
    indices: jax.Array,
    table: jax.Array,
    *,
    block_b: int | None = None,
    use_loop: bool = False,
) -> jax.Array:
    """Gather rows of ``table`` at ``indices``: out[b, :] = table[indices[b], :].

    indices: (B,) int32, table: (N, D) float32 -> (B, D) float32.
    """
    (batch,) = indices.shape
    n, d = table.shape
    block = _block_b_for(batch, block_b)
    grid = (batch // block,)
    return pl.pallas_call(
        functools.partial(_gather_kernel, block_b=block, use_loop=use_loop),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block,), lambda i: (i,)),
            # Whole table visible to every grid step: gather targets are
            # data-dependent, so no useful HBM->VMEM pre-tiling exists for
            # the table itself (on real TPU the table stays in HBM/ANY and
            # rows stream through VMEM; interpret mode just aliases it).
            pl.BlockSpec((n, d), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((block, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((batch, d), table.dtype),
        interpret=True,
    )(indices, table)


def windowed_gather(
    window: jax.Array,
    indices: jax.Array,
    table: jax.Array,
    *,
    block_b: int | None = None,
    use_loop: bool = False,
) -> jax.Array:
    """Gather with indices remapped into the row window ``[window[0], window[0]+window[1])``.

    window: (2,) int32 = [base_row, size_rows]; indices: (B,) int32;
    table: (N, D) f32 -> (B, D) f32.  The coordinator's group-to-chunk
    placement feeds each SM group's window here.
    """
    (batch,) = indices.shape
    n, d = table.shape
    block = _block_b_for(batch, block_b)
    grid = (batch // block,)
    return pl.pallas_call(
        functools.partial(_windowed_gather_kernel, block_b=block, use_loop=use_loop),
        grid=grid,
        in_specs=[
            pl.BlockSpec((2,), lambda i: (0,)),
            pl.BlockSpec((block,), lambda i: (i,)),
            pl.BlockSpec((n, d), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((block, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((batch, d), table.dtype),
        interpret=True,
    )(window, indices, table)


def bag_gather_sum(
    indices: jax.Array,
    table: jax.Array,
    *,
    block_b: int | None = None,
    use_loop: bool = False,
) -> jax.Array:
    """Fixed-size embedding-bag pooling: out[b] = sum_g table[indices[b, g]].

    indices: (B, G) int32, table: (N, D) f32 -> (B, D) f32.
    """
    batch, bag = indices.shape
    n, d = table.shape
    block = _block_b_for(batch, block_b)
    grid = (batch // block,)
    return pl.pallas_call(
        functools.partial(_bag_kernel, block_b=block, bag=bag, use_loop=use_loop),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block, bag), lambda i: (i, 0)),
            pl.BlockSpec((n, d), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((block, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((batch, d), table.dtype),
        interpret=True,
    )(indices, table)

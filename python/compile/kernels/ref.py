"""Pure-jnp oracles for the L1 Pallas kernels.

Every kernel in gather.py has an oracle here; pytest asserts allclose over a
hypothesis-driven sweep of shapes and index distributions.  These are also
the implementations the AOT path uses for the backward pass (scatter-add is
an L2-level op; see model.py).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def gather_rows_ref(indices: jax.Array, table: jax.Array) -> jax.Array:
    """out[b, :] = table[indices[b], :]."""
    return jnp.take(table, indices, axis=0)


def windowed_gather_ref(window: jax.Array, indices: jax.Array, table: jax.Array) -> jax.Array:
    """Gather with indices remapped into [window[0], window[0]+window[1])."""
    remapped = window[0] + jax.lax.rem(indices, window[1])
    return jnp.take(table, remapped, axis=0)


def bag_gather_sum_ref(indices: jax.Array, table: jax.Array) -> jax.Array:
    """out[b] = sum_g table[indices[b, g]]."""
    return jnp.take(table, indices, axis=0).sum(axis=1)


def bag_grad_table_ref(indices: jax.Array, grad_out: jax.Array, n_rows: int) -> jax.Array:
    """Backward of bag_gather_sum w.r.t. the table: scatter-add of grad_out.

    indices: (B, G) int32, grad_out: (B, D) -> (n_rows, D).
    """
    batch, bag = indices.shape
    d = grad_out.shape[1]
    flat_idx = indices.reshape(-1)
    flat_grad = jnp.broadcast_to(grad_out[:, None, :], (batch, bag, d)).reshape(-1, d)
    return jnp.zeros((n_rows, d), grad_out.dtype).at[flat_idx].add(flat_grad)

"""AOT: lower every L2 entry point to HLO *text* + a manifest for the runtime.

HLO text (NOT ``lowered.compiler_ir(...).serialize()``) is the interchange
format: jax >= 0.5 emits HloModuleProtos with 64-bit instruction ids which
the xla crate's xla_extension 0.5.1 rejects (``proto.id() <= INT_MAX``); the
text parser reassigns ids and round-trips cleanly.  See
/opt/xla-example/gen_hlo.py and /opt/xla-example/README.md.

Usage (from python/):  python -m compile.aot --out-dir ../artifacts

Emits one ``<name>.hlo.txt`` per (entry point, shape variant) plus
``manifest.json`` describing every artifact (entry, operand shapes/dtypes,
row/col counts) so the Rust runtime can pick executables by shape without
parsing HLO.
"""

from __future__ import annotations

import argparse
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from compile import model

I32 = jnp.int32
F32 = jnp.float32


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (id-safe interchange)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def spec(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


# Shape variants the coordinator needs.  N is rows of the (scaled-down)
# resident table shard; B is the routed batch size after dynamic batching.
# D=32 f32 == one 128-byte line, the paper's access unit.
DEFAULT_N = 65536
DEFAULT_D = 32
BATCHES = (256, 1024, 4096)
BAG = 8


def build_entries(n: int, d: int, batches: tuple[int, ...], bag: int):
    """Yield (name, fn, example_args, meta) for every artifact."""
    for b in batches:
        yield (
            f"gather_b{b}_n{n}_d{d}",
            model.lookup,
            (spec((b,), I32), spec((n, d), F32)),
            {"entry": "lookup", "b": b, "n": n, "d": d, "operands": ["indices", "table"]},
        )
        yield (
            f"windowed_gather_b{b}_n{n}_d{d}",
            model.windowed_lookup,
            (spec((2,), I32), spec((b,), I32), spec((n, d), F32)),
            {
                "entry": "windowed_lookup",
                "b": b,
                "n": n,
                "d": d,
                "operands": ["window", "indices", "table"],
            },
        )
        yield (
            f"bag_fwd_b{b}_g{bag}_n{n}_d{d}",
            model.bag_forward,
            (spec((b, bag), I32), spec((n, d), F32)),
            {
                "entry": "bag_forward",
                "b": b,
                "g": bag,
                "n": n,
                "d": d,
                "operands": ["indices", "table"],
            },
        )
    # One training-step artifact (fwd+bwd) at the middle batch size.
    b = batches[len(batches) // 2]
    yield (
        f"bag_train_b{b}_g{bag}_n{n}_d{d}",
        model.bag_loss_and_grad,
        (spec((b, bag), I32), spec((n, d), F32), spec((b, d), F32)),
        {
            "entry": "bag_loss_and_grad",
            "b": b,
            "g": bag,
            "n": n,
            "d": d,
            "operands": ["indices", "table", "targets"],
        },
    )


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--out", default=None, help="legacy single-file target (model.hlo.txt)")
    ap.add_argument("--n", type=int, default=DEFAULT_N)
    ap.add_argument("--d", type=int, default=DEFAULT_D)
    ap.add_argument("--bag", type=int, default=BAG)
    args = ap.parse_args()

    out_dir = args.out_dir
    if args.out is not None:
        out_dir = os.path.dirname(args.out) or "."
    os.makedirs(out_dir, exist_ok=True)

    manifest = {"version": 1, "artifacts": []}
    for name, fn, example_args, meta in build_entries(args.n, args.d, BATCHES, args.bag):
        lowered = jax.jit(fn).lower(*example_args)
        text = to_hlo_text(lowered)
        fname = f"{name}.hlo.txt"
        with open(os.path.join(out_dir, fname), "w") as f:
            f.write(text)
        manifest["artifacts"].append({"name": name, "file": fname, **meta})
        print(f"wrote {fname} ({len(text)} chars)")

    with open(os.path.join(out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2)
    print(f"wrote manifest.json ({len(manifest['artifacts'])} artifacts)")

    if args.out is not None:
        # Legacy Makefile stamp: symlink the smallest gather to model.hlo.txt.
        first = manifest["artifacts"][0]["file"]
        dst = args.out
        if os.path.islink(dst) or os.path.exists(dst):
            os.remove(dst)
        os.symlink(first, dst)
        print(f"linked {dst} -> {first}")


if __name__ == "__main__":
    main()

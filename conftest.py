# Make `pytest python/tests/` work from the repo root: the test modules
# import `compile.*` relative to the python/ directory.
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "python"))
